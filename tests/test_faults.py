"""Fault-tolerance tests: the write-ahead request journal (crash ->
resume token identity, in-process and across a real SIGKILL),
transactional hot-swap quarantine of corrupt winner checkpoints, the
deterministic fault-injection harness (stall / oom / disconnect), and
cancellation mid-chunked-prefill / mid-fused-draft resource reclaim."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.lm import init_lm
from repro.serve import journal as journal_mod
from repro.serve.faults import (FaultInjector, InjectedFault,
                                parse_fault_spec)
from repro.serve.journal import RequestJournal
from repro.serve.scheduler import Request, Scheduler

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def served():
    cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                              dtype="float32")
    params, _ = init_lm(cfg, KEY)
    return cfg, params


def _prompts(cfg, n, max_len, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, max_len), 0, cfg.vocab_size),
        np.int32)


# ---------------------------------------------------------------------------
# fault-spec parsing
# ---------------------------------------------------------------------------


def test_parse_fault_spec():
    evs = parse_fault_spec("stall@5:secs=0.2,kill@12,oom@7:hold=3:rank=1")
    assert [(e.kind, e.step) for e in evs] == \
        [("stall", 5), ("oom", 7), ("kill", 12)]     # sorted by step
    assert evs[0].args["secs"] == "0.2"
    assert evs[1].rank == 1 and evs[2].rank == 0
    with pytest.raises(ValueError, match="unknown kind"):
        parse_fault_spec("explode@3")
    with pytest.raises(ValueError, match="kind@step"):
        parse_fault_spec("kill")
    with pytest.raises(ValueError, match="key=val"):
        parse_fault_spec("kill@3:rank")


# ---------------------------------------------------------------------------
# journal: record / replay / resume plumbing (no model needed)
# ---------------------------------------------------------------------------


def test_journal_roundtrip_and_torn_tail(tmp_path):
    path = str(tmp_path / "j.jsonl")
    j = RequestJournal(path)
    r0 = Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new=6,
                 temperature=0.5, seed=9, idem_key="k0")
    r1 = Request(rid=1, prompt=np.arange(3, dtype=np.int32), max_new=4)
    j.record_submit(r0)
    j.record_submit(r1)
    j.step_commit({0: [10, 11], 1: [20]}, [])
    j.step_commit({0: [12]}, [])
    j.record_cancel(1, "cancel")
    j.record_note("shutdown", drained=False)
    j.close()

    entries = journal_mod.replay(path)
    assert set(entries) == {0, 1}
    assert entries[0].tokens == [10, 11, 12] and not entries[0].done
    assert entries[1].cancelled
    assert journal_mod.unfinished(entries) == [0]
    assert journal_mod.idempotency_map(entries) == {"k0": (0, False)}
    assert journal_mod.last_note(path)["kind"] == "shutdown"

    req, prefix = journal_mod.resume_request(entries[0])
    assert prefix == [10, 11, 12]
    assert req.prompt.tolist() == [0, 1, 2, 3, 10, 11, 12]
    assert req.max_new == 3 and req.ntok_base == 3
    assert req.seed == 9 and req.idem_key == "k0"

    # torn tail: a generation that died mid-write loses only the tail
    with open(path, "ab") as f:
        f.write(b'{"t":"tokens","toks":{"0":[99')   # cut mid-record
    torn = journal_mod.replay(path)
    assert torn[0].tokens == [10, 11, 12]           # 99 never landed


def test_resume_scheduler_preloads_finished(tmp_path, served):
    """done / budget-exhausted / eos-hit entries land straight in
    ``results``; only genuinely unfinished ones are requeued."""
    cfg, params = served
    path = str(tmp_path / "j.jsonl")
    j = RequestJournal(path)
    done = Request(rid="a", prompt=np.arange(4, dtype=np.int32),
                   max_new=2)
    eosd = Request(rid="b", prompt=np.arange(4, dtype=np.int32),
                   max_new=8, eos_id=7)
    for r in (done, eosd):
        j.record_submit(r)
    j.step_commit({"a": [1, 2], "b": [5, 7]}, ["a"])
    j.close()
    sched = Scheduler(cfg, params, num_slots=1, max_len=16)
    prefixes = journal_mod.resume_scheduler(sched, journal_mod.replay(path))
    assert prefixes == {} and not sched.queue
    assert sched.results["a"].tolist() == [1, 2]
    assert sched.results["b"].tolist() == [5, 7]    # eos-terminated
    assert sched.stats.journal_replayed == 0


# ---------------------------------------------------------------------------
# crash -> resume token identity (the tentpole acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_crash_resume_token_identity(tmp_path, served, temperature):
    """An injected crash mid-decode, then a FRESH scheduler resuming
    from the journal, emits exactly the uninterrupted token streams —
    greedy and sampled (the rng stream is position-keyed)."""
    cfg, params = served
    toks = _prompts(cfg, 3, 8)
    mk = [Request(rid=i, prompt=toks[i, :4 + 2 * i], max_new=8,
                  temperature=temperature,
                  seed=None if temperature <= 0 else 40 + i)
          for i in range(3)]

    ref = Scheduler(cfg, params, num_slots=2, max_len=32)
    for r in mk:
        ref.submit(dataclasses.replace(r))
    expect = {r.rid: ref.run(max_steps=200)[r.rid].tolist() for r in mk}

    path = str(tmp_path / "j.jsonl")
    s1 = Scheduler(cfg, params, num_slots=2, max_len=32,
                   journal=RequestJournal(path),
                   faults=FaultInjector("crash@4"))
    for r in mk:
        s1.submit(dataclasses.replace(r))
    with pytest.raises(InjectedFault):
        s1.run(max_steps=200)
    assert s1.stats.fault_injected == 1
    # the crashed generation made real progress but finished nothing
    entries = journal_mod.replay(path)
    assert journal_mod.unfinished(entries)

    s2 = Scheduler(cfg, params, num_slots=2, max_len=32)
    prefixes = journal_mod.resume_scheduler(s2, entries)
    assert s2.stats.journal_replayed == len(prefixes) > 0
    res = journal_mod.stitched_results(s2.run(max_steps=200), prefixes)
    assert {rid: t.tolist() for rid, t in res.items()} == expect


def test_subprocess_sigkill_resume_token_identity(tmp_path):
    """The real thing: ``launch/serve.py --fault-spec kill@N`` dies by
    SIGKILL mid-decode (no flush, no atexit); a second run with
    ``--resume-journal`` reproduces the uninterrupted streams."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    jpath = str(tmp_path / "j.jsonl")
    base = [sys.executable, "-m", "repro.launch.serve",
            "--arch", "qwen3-0.6b", "--smoke", "--requests", "2",
            "--max-new", "8", "--temperature", "0.7",
            "--prompt-lens", "4,6"]
    ref = subprocess.run(
        base + ["--out-json", str(tmp_path / "ref.json")],
        env=env, capture_output=True, text=True, timeout=300)
    assert ref.returncode == 0, ref.stderr[-2000:]
    killed = subprocess.run(
        base + ["--journal", jpath, "--fault-spec", "kill@3"],
        env=env, capture_output=True, text=True, timeout=300)
    assert killed.returncode == -9, (killed.returncode,
                                     killed.stderr[-2000:])
    resumed = subprocess.run(
        base + ["--resume-journal", jpath,
                "--out-json", str(tmp_path / "res.json")],
        env=env, capture_output=True, text=True, timeout=300)
    assert resumed.returncode == 0, resumed.stderr[-2000:]
    a = json.load(open(tmp_path / "ref.json"))["results"]
    b = json.load(open(tmp_path / "res.json"))["results"]
    assert a == b and a


# ---------------------------------------------------------------------------
# transactional hot-swap: corrupt winners are quarantined
# ---------------------------------------------------------------------------


def _write_winner(ckpt_dir, step, params, checksum=True):
    from repro.checkpoint import ckpt
    from repro.serve import registry as reg
    path = reg.winner_path(str(ckpt_dir), step)
    ckpt.save(path, {"params": params}, metadata={"step": step,
                                                  "trainer": 0})
    if checksum:
        reg.write_checksum(path)
    return path


def test_registry_quarantines_corrupt_winner(tmp_path, served):
    """A torn winner never crashes ``refresh()`` and never changes the
    served weights; the NEXT good export swaps in normally."""
    from repro.serve import registry as reg
    cfg, params = served
    _write_winner(tmp_path, 1, params)
    r = reg.ModelRegistry(str(tmp_path), params)
    assert r.load() is not None and r.step == 1

    p2 = _write_winner(tmp_path, 2, params)
    size = os.path.getsize(p2)
    with open(p2, "r+b") as f:                  # torn write
        f.truncate(size // 2)
    assert r.refresh() is False                 # never raises
    assert r.step == 1 and r.rejected_corrupt == 1
    assert os.path.exists(p2 + ".corrupt")      # renamed away
    assert r.refresh() is False                 # no re-trip

    _write_winner(tmp_path, 3, params)          # recovery path
    assert r.refresh() is True and r.step == 3
    assert r.rejected_corrupt == 1

    # follower semantics: a corrupt load must RAISE, not diverge
    p4 = _write_winner(tmp_path, 4, params)
    with open(p4, "r+b") as f:
        f.truncate(os.path.getsize(p4) // 2)
    strict = reg.ModelRegistry(str(tmp_path), params)
    with pytest.raises(ValueError, match="corrupt or torn"):
        strict.load_step(4)


def test_corrupt_winner_during_polling_serves_on(tmp_path, served):
    """Scheduler-level: the ``corrupt`` fault truncates the newest
    winner right before a ``--watch-every`` poll; the driver keeps
    serving the old weights and completes every request."""
    from repro.serve import registry as reg
    cfg, params = served
    _write_winner(tmp_path, 1, params)
    registry = reg.ModelRegistry(str(tmp_path), params)
    serving = registry.load()
    _write_winner(tmp_path, 2, params)          # the poll's next target
    # corrupt@1 fires BEFORE step 1's registry poll — the very first
    # refresh sees the torn file
    sched = Scheduler(cfg, serving, num_slots=2, max_len=32,
                      registry=registry, watch_every=1,
                      faults=FaultInjector("corrupt@1"))
    toks = _prompts(cfg, 2, 8)
    for i in range(2):
        sched.submit(Request(rid=i, prompt=toks[i, :6], max_new=6))
    res = sched.run(max_steps=100)
    assert len(res) == 2 and sched.stats.completed == 2
    assert sched.stats.fault_injected == 1
    assert sched.stats.swap_rejected_corrupt == 1
    assert registry.step == 1                   # old winner kept serving
    assert sched.stats.hot_swaps == 0


# ---------------------------------------------------------------------------
# stall / oom / disconnect
# ---------------------------------------------------------------------------


def test_stall_oom_disconnect_faults(served):
    """The remaining fault kinds: a stall slows one step, oom holds
    admission shut, disconnect cancels the oldest in-flight request —
    all counted in ``fault_injected``, all resources reclaimed."""
    cfg, params = served
    toks = _prompts(cfg, 3, 8)
    sched = Scheduler(cfg, params, num_slots=2, max_len=32,
                      faults=FaultInjector(
                          "stall@1:secs=0.01,oom@2:hold=2,"
                          "disconnect@5:rid=0"))
    for i in range(3):
        sched.submit(Request(rid=i, prompt=toks[i, :6], max_new=8))
    res = sched.run(max_steps=200)
    assert sched.stats.fault_injected == 3
    assert sched.stats.cancelled == 1 and 0 not in res
    assert sorted(res) == [1, 2] and all(len(t) == 8
                                         for t in res.values())
    assert sched.pool.free_slots == 2
    assert sched.pool.blocks.used_blocks == 0


def test_oom_fault_blocks_admission(served):
    """While an ``oom`` event holds, the admission phase admits
    nothing — queued requests stay queued until the hold expires."""
    cfg, params = served
    toks = _prompts(cfg, 1, 8)
    sched = Scheduler(cfg, params, num_slots=2, max_len=32,
                      faults=FaultInjector("oom@1:hold=3"))
    sched.submit(Request(rid=0, prompt=toks[0, :4], max_new=4))
    for _ in range(3):                           # steps 1..3: held
        sched.step()
        assert len(sched.queue) == 1 and not sched.active \
            and not sched.prefilling
    res = sched.run(max_steps=50)                # hold expired: admits
    assert res[0].shape == (4,)


# ---------------------------------------------------------------------------
# cancel mid-chunked-prefill / mid-fused-draft (resource reclaim)
# ---------------------------------------------------------------------------


def test_cancel_mid_chunked_prefill_reclaims_pages(served):
    """cancel() landing while a request is mid-chunked-prefill frees
    its slot and every allocated page (no orphaned partial prefill)."""
    cfg, params = served
    toks = _prompts(cfg, 2, 16)
    sched = Scheduler(cfg, params, num_slots=2, max_len=32,
                      block_size=4, prefill_chunk=4)
    sched.submit(Request(rid=0, prompt=toks[0, :14], max_new=6))
    sched.step()                                 # first chunk only
    assert 0 in sched.prefilling                 # mid-prefill
    assert sched.cancel(0) is True
    assert sched.pool.free_slots == 2
    assert sched.pool.blocks.used_blocks == 0
    assert sched.stats.cancelled == 1
    # the pool is clean: a follow-up request runs normally
    sched.submit(Request(rid=1, prompt=toks[1, :6], max_new=4))
    res = sched.run(max_steps=50)
    assert 0 not in res and res[1].shape == (4,)
    assert sched.pool.blocks.used_blocks == 0


def test_cancel_during_fused_draft_reclaims_drafter_rows(served):
    """cancel() while speculative decoding is active releases BOTH the
    target pool slot/pages and the drafter layout's row for that rid."""
    cfg, params = served
    toks = _prompts(cfg, 2, 10)
    sched = Scheduler(cfg, params, num_slots=2, max_len=32,
                      draft_params=params, spec_tokens=3)
    assert sched.draft is not None
    for i in range(2):
        sched.submit(Request(rid=i, prompt=toks[i, :6], max_new=10))
    for _ in range(2):                           # into the spec rounds
        sched.step()
    assert 0 in sched.active
    assert sched.cancel(0) is True
    assert sched.draft.layout.free_slots >= 1    # drafter row released
    res = sched.run(max_steps=100)
    assert 0 not in res and len(res[1]) == 10
    assert sched.pool.free_slots == 2
    assert sched.pool.blocks.used_blocks == 0
    assert sched.draft.layout.free_slots == sched.draft.layout.num_slots


def test_cancel_queued_request_is_journaled(tmp_path, served):
    """A cancel that lands while the request is still queued writes a
    ``cancel`` record so a resume never re-runs it."""
    cfg, params = served
    path = str(tmp_path / "j.jsonl")
    sched = Scheduler(cfg, params, num_slots=1, max_len=16,
                      journal=RequestJournal(path))
    sched.submit(Request(rid=5, prompt=np.arange(4, dtype=np.int32),
                         max_new=4))
    assert sched.cancel(5) is True
    sched.journal.close()
    entries = journal_mod.replay(path)
    assert entries[5].cancelled
    s2 = Scheduler(cfg, params, num_slots=1, max_len=16)
    assert journal_mod.resume_scheduler(s2, entries) == {}
    assert not s2.queue and 5 not in s2.results
