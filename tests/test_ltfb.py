"""LTFB algorithm tests: pairing properties (hypothesis), tournament
semantics, generator-scope exchange, and the mesh-native butterfly step
on 8 simulated devices (subprocess)."""
import subprocess
import sys

import numpy as np

from hypothesis_compat import given, settings, st

from repro.core import ltfb


@given(st.integers(2, 64), st.integers(0, 1000), st.integers(0, 10))
@settings(max_examples=60, deadline=None)
def test_random_pairing_is_involution(k, round_idx, seed):
    p = ltfb.random_pairing(k, round_idx, seed)
    assert p.shape == (k,)
    # involution: partner of my partner is me
    assert np.all(p[p] == np.arange(k))
    # at most one self-pair when k is even... (odd k has >= 1)
    selfs = int(np.sum(p == np.arange(k)))
    assert selfs == (k % 2)


@given(st.integers(1, 6), st.integers(0, 20))
@settings(max_examples=40, deadline=None)
def test_butterfly_pairing_is_involution_and_cycles(log_k, round_idx):
    k = 2 ** log_k
    p = ltfb.butterfly_pairing(k, round_idx)
    assert np.all(p[p] == np.arange(k))
    assert not np.any(p == np.arange(k))  # never self-pairs
    # over log2(k) rounds, the union of pairings connects everyone
    reached = {0}
    for r in range(log_k):
        pr = ltfb.butterfly_pairing(k, r)
        reached |= {int(pr[i]) for i in list(reached)}
    assert reached == set(range(k))


def test_random_pairing_respects_dead_trainers():
    alive = [True, False, True, True, False, True]
    p = ltfb.random_pairing(6, 3, 0, alive)
    assert p[1] == 1 and p[4] == 4          # dead trainers self-pair
    assert np.all(p[p] == np.arange(6))


def test_host_tournament_keeps_better_model():
    # population of scalar "models"; metric = distance to 3.0 on local data
    pop = [{"w": np.float32(i)} for i in range(4)]

    def metric(idx, params):
        return abs(float(params["w"]) - 3.0)

    partner = np.array([1, 0, 3, 2])
    winners, log = ltfb.host_tournament(pop, metric, partner, "full")
    assert float(winners[0]["w"]) == 1.0     # 1 beats 0
    assert float(winners[1]["w"]) == 1.0
    assert float(winners[2]["w"]) == 3.0     # 3 beats 2
    assert float(winners[3]["w"]) == 3.0


def test_generator_scope_keeps_discriminator_local():
    pop = [{"gen": {"w": np.float32(i)}, "disc": {"d": np.float32(10 + i)}}
           for i in range(2)]

    def metric(idx, params):
        return abs(float(params["gen"]["w"]) - 1.0)

    winners, _ = ltfb.host_tournament(pop, metric, np.array([1, 0]),
                                      "generator")
    # trainer 0 adopts gen of trainer 1 but keeps its own discriminator
    assert float(winners[0]["gen"]["w"]) == 1.0
    assert float(winners[0]["disc"]["d"]) == 10.0
    assert float(winners[1]["disc"]["d"]) == 11.0


def test_split_merge_scope_roundtrip():
    params = {"gen": {"a": 1}, "disc": {"b": 2}}
    ex, loc = ltfb.split_scope(params, "generator")
    assert ex == {"a": 1}
    merged = ltfb.merge_scope({"a": 9}, loc, "generator")
    assert merged == {"gen": {"a": 9}, "disc": {"b": 2}}


MULTIDEV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core import ltfb

K = 8
mesh = Mesh(np.asarray(jax.devices()).reshape(K, 1), ("trainer", "model"))

def metric(params, batch):
    return jnp.mean(jnp.abs(params["w"] - batch["t"]))

params = {"w": jnp.arange(K, dtype=jnp.float32).reshape(K, 1)}
batch = {"t": jnp.full((K, 4), 3.0)}
step = ltfb.make_ltfb_step(metric, K, mesh, axis="trainer", scope="full")
p = params
for r in range(6):
    p, ml, mo = step(p, batch, jnp.int32(r))
assert np.all(np.asarray(p["w"]).ravel() == 3.0), np.asarray(p["w"])
print("OK")
"""


def test_mesh_native_butterfly_propagates_best(tmp_path):
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    import os
    full_env = dict(os.environ)
    full_env.update(env)
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, env=full_env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


QUANTIZED_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core import ltfb

K = 8
mesh = Mesh(np.asarray(jax.devices()).reshape(K, 1), ("trainer", "model"))

def metric(params, batch):
    return jnp.mean(jnp.abs(params["w"] - batch["t"]))

params = {"w": jnp.arange(K, dtype=jnp.float32).reshape(K, 1) * 10.0}
batch = {"t": jnp.full((K, 4), 30.0)}
step = ltfb.make_ltfb_step(metric, K, mesh, axis="trainer", scope="full",
                           quantize=True)
p = params
for r in range(6):
    p, ml, mo = step(p, batch, jnp.int32(r))
w = np.asarray(p["w"]).ravel()
# int8-quantized exchange: winner propagates within quantization error
assert np.all(np.abs(w - 30.0) < 0.5), w
print("OK")
"""


def test_quantized_exchange_propagates_within_tolerance():
    import os
    env = dict(os.environ)
    env.update({"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
                "PYTHONPATH": "src"})
    r = subprocess.run([sys.executable, "-c", QUANTIZED_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
