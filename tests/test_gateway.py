"""Gateway tests: streaming order/parity, SLO-aware admission
(max-queue overload -> 429, TTFT-deadline shedding), bounded-buffer
backpressure, client-disconnect cancellation, and the scheduler-level
max_queue / cancel regressions the gateway relies on."""
import asyncio
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.lm import init_lm
from repro.serve.gateway import Gateway, _Stream
from repro.serve.scheduler import Overloaded, Request, Scheduler

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def served():
    cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                              dtype="float32")
    params, _ = init_lm(cfg, KEY)
    return cfg, params


def _prompt(cfg, n=8, seed=3):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, n).tolist()


# -- raw HTTP client helpers (stdlib only, like the gateway itself) ---------


async def _http(port, method, path, body=None, read_all=True,
                headers=None):
    r, w = await asyncio.open_connection("127.0.0.1", port)
    payload = json.dumps(body).encode() if body is not None else b""
    extra = "".join(f"{k}: {v}\r\n" for k, v in (headers or {}).items())
    w.write((f"{method} {path} HTTP/1.1\r\nHost: t\r\n{extra}"
             f"Content-Length: {len(payload)}\r\n\r\n").encode() + payload)
    await w.drain()
    data = await r.read() if read_all else b""
    w.close()
    return data.decode()


def _status(resp: str) -> int:
    return int(resp.split()[1])


def _ndjson(resp: str):
    """Decode a chunked NDJSON body into its records."""
    body = resp.split("\r\n\r\n", 1)[1]
    recs = []
    while body:
        size, _, rest = body.partition("\r\n")
        n = int(size, 16)
        if n == 0:
            break
        recs.append(json.loads(rest[:n]))
        body = rest[n + 2:]
    return recs


def _run(coro, timeout=300):
    return asyncio.new_event_loop().run_until_complete(
        asyncio.wait_for(coro, timeout))


# -- scheduler-level admission regressions ----------------------------------


def test_scheduler_max_queue_overload(served):
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=2, max_len=32, max_queue=2)
    p = np.asarray(_prompt(cfg), np.int32)
    sched.submit(Request(rid="a", prompt=p, max_new=4))
    sched.submit(Request(rid="b", prompt=p, max_new=4))
    with pytest.raises(Overloaded):
        sched.submit(Request(rid="c", prompt=p, max_new=4))
    assert sched.stats.shed_overload == 1
    # shedding is not rejection: the request was well-formed
    assert sched.stats.rejected == 0
    # draining the queue reopens admission
    sched.run()
    sched.submit(Request(rid="c", prompt=p, max_new=4))
    assert len(sched.queue) == 1


def test_scheduler_cancel_releases_resources(served):
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=2, max_len=32)
    p = np.asarray(_prompt(cfg), np.int32)
    for rid in ("a", "b", "c"):
        sched.submit(Request(rid=rid, prompt=p, max_new=8))
    sched.step()                      # a (and maybe b) admitted
    free0 = sched.pool.blocks.free_blocks
    assert sched.cancel("a")          # in-flight
    assert sched.cancel("c")          # still queued
    assert not sched.cancel("zz")     # unknown
    assert sched.pool.blocks.free_blocks > free0
    assert sched.stats.cancelled == 2
    results = sched.run()             # b must still complete
    assert set(results) == {"b"}
    assert "a" not in sched.results and "c" not in sched.results


def test_scheduler_shed_expired_deadline(served):
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=32)
    p = np.asarray(_prompt(cfg), np.int32)
    sched.submit(Request(rid="a", prompt=p, max_new=4))
    sched.step()                      # occupy the only slot
    sched.submit(Request(rid="late", prompt=p, max_new=4,
                         ttft_deadline_ms=1e-3))
    sched.submit(Request(rid="ok", prompt=p, max_new=4))
    shed = sched.shed_expired()
    assert shed == ["late"]
    assert sched.stats.shed_deadline == 1
    results = sched.run()
    assert set(results) == {"a", "ok"}


# -- gateway integration ----------------------------------------------------


def test_gateway_stream_matches_nonstream_and_orders_tokens(served):
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=2, max_len=32)
    gw = Gateway(sched)

    async def go():
        await gw.start()
        body = {"prompt": _prompt(cfg), "max_new": 6}
        streamed = await _http(gw.port, "POST", "/v1/generate",
                               {**body, "rid": "s"})
        plain = await _http(gw.port, "POST", "/v1/generate",
                            {**body, "rid": "p", "stream": False})
        health = await _http(gw.port, "GET", "/healthz")
        metrics = await _http(gw.port, "GET", "/metrics",
                              headers={"Accept": "application/json"})
        missing = await _http(gw.port, "GET", "/nope")
        await gw.stop()
        return streamed, plain, health, metrics, missing

    streamed, plain, health, metrics, missing = _run(go())
    recs = _ndjson(streamed)
    toks = [r["token"] for r in recs if "token" in r]
    assert recs[-1] == {"rid": "s", "done": True, "ntok": 6}
    assert _status(plain) == 200
    assert json.loads(plain.split("\r\n\r\n", 1)[1])["tokens"] == toks
    assert len(toks) == 6
    assert _status(health) == 200 and _status(missing) == 404
    md = json.loads(metrics.split("\r\n\r\n", 1)[1])
    assert md["completed"] == 2 and md["submitted"] == 2


def test_gateway_sheds_overload_with_429(served):
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=72,
                      max_queue=1)
    gw = Gateway(sched)

    async def go():
        await gw.start()
        # occupy the single slot with a long request, confirmed by its
        # first streamed token (so admission has definitely happened)
        r, w = await asyncio.open_connection("127.0.0.1", gw.port)
        body = json.dumps({"prompt": _prompt(cfg), "max_new": 64,
                           "rid": "hog"}).encode()
        w.write((f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        await w.drain()
        await r.readuntil(b"token")
        # now burst: one fits the queue, the rest must shed with 429
        burst = await asyncio.gather(*[
            _http(gw.port, "POST", "/v1/generate",
                  {"prompt": _prompt(cfg), "max_new": 4,
                   "rid": f"b{i}"}) for i in range(3)])
        w.close()
        await gw.stop()
        return burst

    burst = _run(go())
    codes = sorted(_status(b) for b in burst)
    assert codes.count(429) >= 1, codes
    shed = [b for b in burst if _status(b) == 429]
    assert all("Retry-After" in b for b in shed)
    assert sched.stats.shed_overload >= 1


def test_gateway_deadline_shed_is_429(served):
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=72)
    gw = Gateway(sched)

    async def go():
        await gw.start()
        r, w = await asyncio.open_connection("127.0.0.1", gw.port)
        body = json.dumps({"prompt": _prompt(cfg), "max_new": 64,
                           "rid": "hog"}).encode()
        w.write((f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        await w.drain()
        await r.readuntil(b"token")    # slot occupied
        late = await _http(gw.port, "POST", "/v1/generate",
                           {"prompt": _prompt(cfg), "max_new": 4,
                            "rid": "late", "ttft_deadline_ms": 0.001})
        w.close()
        await gw.stop()
        return late

    late = _run(go())
    assert _status(late) == 429
    assert "deadline" in late
    assert sched.stats.shed_deadline == 1


def test_gateway_bad_request_is_400(served):
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=32)
    gw = Gateway(sched)

    async def go():
        await gw.start()
        missing = await _http(gw.port, "POST", "/v1/generate", {})
        toolong = await _http(gw.port, "POST", "/v1/generate",
                              {"prompt": _prompt(cfg, 8),
                               "max_new": 4096})
        await gw.stop()
        return missing, toolong

    missing, toolong = _run(go())
    assert _status(missing) == 400
    assert _status(toolong) == 400
    assert sched.stats.rejected == 1


def test_backpressure_cancels_slow_consumer(served):
    # driver-side publication unit: a consumer that stops draining its
    # bounded stream queue gets the request cancelled, not an
    # unbounded buffer
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=32)
    gw = Gateway(sched, stream_buffer=2)
    loop = asyncio.new_event_loop()
    gw.loop = loop
    st = _Stream(rid="slow", q=asyncio.Queue())
    gw._streams["slow"] = st
    for i in range(5):                # consumer never drains
        gw._post(st, ("tok", i))
    loop.run_until_complete(asyncio.sleep(0))
    assert st.error is not None and "backpressure" in st.error
    assert list(gw._cancels) == ["slow"]
    assert st.q.qsize() == 2          # bounded: only the buffer landed
    assert "slow" not in gw._streams
    # further publications are dropped, not queued
    gw._post(st, ("tok", 99))
    assert st.q.qsize() == 2
    loop.close()


def test_gateway_client_disconnect_frees_slot(served):
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=136)
    gw = Gateway(sched)

    async def go():
        await gw.start()
        r, w = await asyncio.open_connection("127.0.0.1", gw.port)
        body = json.dumps({"prompt": _prompt(cfg), "max_new": 128,
                           "rid": "gone"}).encode()
        w.write((f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        await w.drain()
        await r.readuntil(b"token")
        # hard-close mid-stream: the server must cancel the request
        w.transport.abort()
        # the freed slot must serve a new request to completion
        nxt = await _http(gw.port, "POST", "/v1/generate",
                          {"prompt": _prompt(cfg, seed=5), "max_new": 4,
                           "rid": "after", "stream": False})
        await gw.stop()
        return nxt

    nxt = _run(go())
    assert _status(nxt) == 200
    assert len(json.loads(nxt.split("\r\n\r\n", 1)[1])["tokens"]) == 4
    assert sched.stats.cancelled == 1
    assert "gone" not in sched.results


# -- fault tolerance: drain + idempotent retries ----------------------------


def _header(resp: str, name: str):
    for line in resp.split("\r\n\r\n", 1)[0].split("\r\n")[1:]:
        k, _, v = line.partition(":")
        if k.strip().lower() == name.lower():
            return v.strip()
    return None


def test_gateway_drain_refuses_new_work_with_retry_after(served):
    """begin_drain(): /readyz flips to 503 and new generates get 503 +
    Retry-After, while an in-flight request keeps streaming to done."""
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=40)
    gw = Gateway(sched)

    async def go():
        await gw.start()
        ready_before = await _http(gw.port, "GET", "/readyz")
        r, w = await asyncio.open_connection("127.0.0.1", gw.port)
        body = json.dumps({"prompt": _prompt(cfg), "max_new": 16,
                           "rid": "inflight"}).encode()
        w.write((f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        await w.drain()
        await r.readuntil(b"token")            # streaming has begun
        gw.begin_drain()
        ready_after = await _http(gw.port, "GET", "/readyz")
        refused = await _http(gw.port, "POST", "/v1/generate",
                              {"prompt": _prompt(cfg), "max_new": 2,
                               "rid": "late", "stream": False})
        rest = (await r.read()).decode()       # in-flight finishes
        w.close()
        while not gw.drained():
            await asyncio.sleep(0.01)
        await gw.stop()
        return ready_before, ready_after, refused, rest

    ready_before, ready_after, refused, rest = _run(go())
    assert _status(ready_before) == 200
    assert _status(ready_after) == 503
    assert _header(ready_after, "Retry-After") is not None
    assert _status(refused) == 503
    assert _header(refused, "Retry-After") is not None
    assert "draining" in refused
    assert '"done": true' in rest.lower()
    assert "late" not in sched.results and "inflight" in sched.results


def test_gateway_idempotency_key_dedups_retries(served):
    """The same Idempotency-Key never double-admits: 409 while the
    original is in flight, a 200 replay once it finished."""
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=40)
    gw = Gateway(sched)

    async def go():
        await gw.start()
        first = await _http(gw.port, "POST", "/v1/generate",
                            {"prompt": _prompt(cfg), "max_new": 4,
                             "rid": "orig", "stream": False},
                            headers={"Idempotency-Key": "abc"})
        replay = await _http(gw.port, "POST", "/v1/generate",
                             {"prompt": _prompt(cfg), "max_new": 4},
                             headers={"Idempotency-Key": "abc"})
        fresh = await _http(gw.port, "POST", "/v1/generate",
                            {"prompt": _prompt(cfg), "max_new": 4,
                             "rid": "other", "stream": False},
                            headers={"Idempotency-Key": "xyz"})
        await gw.stop()
        return first, replay, fresh

    first, replay, fresh = _run(go())
    assert _status(first) == 200 and _status(fresh) == 200
    body1 = json.loads(first.split("\r\n\r\n", 1)[1])
    body2 = json.loads(replay.split("\r\n\r\n", 1)[1])
    assert _status(replay) == 200 and body2["idempotent_replay"]
    assert body2["tokens"] == body1["tokens"]
    assert body2["rid"] == "orig"
    assert sched.stats.submitted == 2          # replay never admitted


def test_gateway_idempotency_conflict_while_in_flight(served):
    """A retry racing the original gets 409 + Retry-After instead of a
    duplicate stream; seeding from a journal map works the same way."""
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=136)
    gw = Gateway(sched)

    async def go():
        await gw.start()
        r, w = await asyncio.open_connection("127.0.0.1", gw.port)
        body = json.dumps({"prompt": _prompt(cfg), "max_new": 128,
                           "rid": "slow"}).encode()
        w.write((f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                 f"Idempotency-Key: race\r\n"
                 f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        await w.drain()
        await r.readuntil(b"token")            # admitted, streaming
        dup = await _http(gw.port, "POST", "/v1/generate",
                          {"prompt": _prompt(cfg), "max_new": 4},
                          headers={"Idempotency-Key": "race"})
        w.transport.abort()                    # let the run end fast
        await gw.stop()
        return dup

    dup = _run(go())
    assert _status(dup) == 409
    assert _header(dup, "Retry-After") is not None
    assert json.loads(dup.split("\r\n\r\n", 1)[1])["rid"] == "slow"


def test_gateway_seed_idempotency_replays_journaled_result(served):
    """Across a restart: a finished rid preloaded from the journal
    (results + idempotency map) satisfies a client retry without
    re-decoding."""
    from repro.serve import journal as journal_mod

    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=40)
    sched.results["done-rid"] = np.asarray([3, 1, 4], np.int32)
    gw = Gateway(sched)
    gw.seed_idempotency({"restart-key": ("done-rid", True)})

    async def go():
        await gw.start()
        resp = await _http(gw.port, "POST", "/v1/generate",
                           {"prompt": _prompt(cfg), "max_new": 4},
                           headers={"Idempotency-Key": "restart-key"})
        await gw.stop()
        return resp

    resp = _run(go())
    assert _status(resp) == 200
    body = json.loads(resp.split("\r\n\r\n", 1)[1])
    assert body["tokens"] == [3, 1, 4] and body["idempotent_replay"]
    assert sched.stats.submitted == 0          # nothing re-decoded
