"""Import shim so mixed test modules collect without hypothesis.

``from hypothesis_compat import given, settings, st`` — with hypothesis
installed these are the real objects; in a bare environment ``@given``
marks just the property tests as skipped while the rest of the module
still runs (``pip install -e .[test]`` for full coverage).
"""
import pytest

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
except ImportError:
    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed "
                       "(pip install -e .[test])")(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _StrategyStub()
