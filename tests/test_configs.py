"""Architecture configs must match the assigned literature specs exactly."""
import pytest

from repro.configs.registry import get_config

SPEC = {
    # arch: (L, d_model, H, kv, d_ff, vocab)
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
    "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
    "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
    "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
    "granite-8b": (36, 4096, 32, 8, 14336, 49152),
    "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
}

MOE_SPEC = {
    "phi3.5-moe-42b-a6.6b": (16, 2, 0),
    "deepseek-moe-16b": (64, 6, 2),
    "jamba-1.5-large-398b": (16, 2, 0),
}


@pytest.mark.parametrize("arch", sorted(SPEC))
def test_full_config_matches_spec(arch):
    cfg = get_config(arch)
    L, d, H, kv, ff, V = SPEC[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == V


@pytest.mark.parametrize("arch", sorted(MOE_SPEC))
def test_moe_config_matches_spec(arch):
    cfg = get_config(arch)
    E, k, shared = MOE_SPEC[arch]
    assert cfg.moe.num_experts == E
    assert cfg.moe.top_k == k
    assert cfg.moe.num_shared_experts == shared


def test_family_tags():
    fam = {a: get_config(a).family for a in SPEC}
    assert fam["phi3.5-moe-42b-a6.6b"] == "moe"
    assert fam["xlstm-125m"] == "ssm"
    assert fam["qwen2-vl-7b"] == "vlm"
    assert fam["jamba-1.5-large-398b"] == "hybrid"
    assert fam["musicgen-medium"] == "audio"
    assert fam["granite-8b"] == "dense"


def test_arch_details():
    assert get_config("qwen3-0.6b").qk_norm
    assert get_config("qwen3-0.6b").resolved_head_dim == 128
    assert get_config("codeqwen1.5-7b").qkv_bias
    assert get_config("qwen2.5-3b").qkv_bias
    assert get_config("qwen2-vl-7b").use_mrope
    cfg = get_config("jamba-1.5-large-398b")
    kinds = cfg.layer_kinds()
    # 1:7 attention:mamba interleave
    assert kinds.count("a") * 7 == kinds.count("M")
    ds = get_config("deepseek-moe-16b")
    assert ds.moe.first_k_dense == 1 and ds.moe.dense_d_ff == 10944
    # smoke configs are same-family but small
    for a in SPEC:
        sm = get_config(a, smoke=True)
        assert sm.family == get_config(a).family
        assert sm.param_count() < 0.01 * max(get_config(a).param_count(), 1)
