"""End-to-end behaviour tests for the paper's system.

The full LTFB pipeline: synthetic JAG -> bundled files -> distributed
data store -> CycleGAN trainers -> tournament -> validation; plus the
serving engine and the checkpoint/restart lifecycle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.configs.icf_cyclegan import SMOKE as CCFG
from repro.core.population import Population, TrainerFns
from repro.data import jag
from repro.datastore.store import DataStore, PrefetchLoader, partition_files
from repro.train.steps import make_gan_steps


@pytest.fixture(scope="module")
def jag_data():
    xs = jag.sample_inputs(4096 + 512, seed=0)
    sim = jag.jag_simulate(xs, CCFG.image_size)
    return sim["x"], jag.flatten_outputs(sim)


def test_cyclegan_learns_on_jag(jag_data):
    """Paper Figs. 7/8 proxy: the surrogate must actually learn."""
    x, y = jag_data
    init, train_step, metric = make_gan_steps(
        CCFG, OptimizerConfig(name="adam", lr=1e-3))  # paper settings
    params, opt_state, hp = init(0)
    val = {"x": jnp.asarray(x[4096:]), "y": jnp.asarray(y[4096:])}
    m0 = float(metric(params, val))
    rng = np.random.default_rng(0)
    for _ in range(150):
        idx = rng.integers(0, 4096, 128)
        batch = {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}
        params, opt_state, _ = train_step(params, opt_state, batch, hp)
    m1 = float(metric(params, val))
    assert m1 < 0.6 * m0, (m0, m1)


def test_ltfb_beats_or_matches_k_independent(jag_data):
    """Paper Fig. 13: LTFB >= K-independent on held-out validation."""
    x, y = jag_data
    n, K = 4096, 4
    val = {"x": jnp.asarray(x[n:]), "y": jnp.asarray(y[n:])}
    init, train_step, metric = make_gan_steps(
        CCFG, OptimizerConfig(name="adam", lr=1e-3))
    fns = TrainerFns(init, train_step, metric)

    def mk():
        def loader_for(k):
            rng = np.random.default_rng(77 + k)
            pool = np.arange(k, n, K)
            def loader():
                idx = rng.choice(pool, 128)
                return {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}
            return loader
        loaders = [loader_for(k) for k in range(K)]
        tb = [[{"x": jnp.asarray(x[np.arange(k, n, K)[:256]]),
                "y": jnp.asarray(y[np.arange(k, n, K)[:256]])}]
              for k in range(K)]
        return loaders, tb

    loaders, tb = mk()
    ltfb = Population(fns, loaders, tb, scope="generator", seed=1,
                      perturb_hparams=False)
    ltfb.run(rounds=4, steps_per_round=25)
    v_ltfb = ltfb.best_metric(val)

    loaders, tb = mk()
    indep = Population(fns, loaders, tb, scope="generator", seed=1,
                       perturb_hparams=False)
    for _ in range(4):
        indep.train_round(25)
    v_ind = indep.best_metric(val)
    # identical data, seeds and step budget: the tournament may only help
    # (small-scale noise tolerance 25%)
    assert v_ltfb <= v_ind * 1.25, (v_ltfb, v_ind)


def test_full_pipeline_store_to_training(tmp_path):
    """Bundled files -> partitioned stores -> prefetch -> training."""
    paths = jag.write_bundles(str(tmp_path), 1000, 125,
                              image_size=CCFG.image_size, seed=0)
    part = partition_files(paths, 2, 0)          # trainer 0's partition
    store = DataStore(part, jag.read_bundle, num_ranks=2, mode="preload")
    store.preload()
    loader = PrefetchLoader(store, batch_size=64, depth=2)
    init, train_step, metric = make_gan_steps(CCFG, OptimizerConfig())
    params, opt_state, hp = init(0)
    try:
        for _ in range(5):
            raw = loader.next()
            batch = {"x": jnp.asarray(raw["x"]),
                     "y": jnp.asarray(jag.flatten_outputs(raw))}
            params, opt_state, m = train_step(params, opt_state, batch, hp)
        assert np.isfinite(float(m["g_loss"]))
    finally:
        loader.close()
    assert store.stats.file_opens == len(part)   # preload: one open each


def test_serve_engine_generates():
    from repro.configs.registry import get_config
    from repro.models.lm import init_lm
    from repro.serve.engine import Engine

    cfg = get_config("qwen3-0.6b", smoke=True)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=48)
    prompts = jnp.ones((2, 16), jnp.int32)
    out = engine.generate(prompts, steps=8)
    assert out.shape == (2, 24)
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.vocab_size))
    # determinism of greedy decode
    out2 = engine.generate(prompts, steps=8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_dryrun_registry_covers_spec():
    """32 cells: 10 archs x 3 shapes + 2 sub-quadratic long_500k."""
    from repro.configs.registry import dryrun_cells
    cells = dryrun_cells()
    assert len(cells) == 32
    archs = {a for a, _ in cells}
    assert len(archs) == 10
    long_archs = {a for a, s in cells if s == "long_500k"}
    assert long_archs == {"xlstm-125m", "jamba-1.5-large-398b"}
