"""Paged-attention serving tests: kernel-vs-oracle, paged-vs-dense
decode parity across attention families (incl. hybrid), chunked-prefill
equivalence, lazy page-overflow allocation, prefix-sharing refcounts,
and drain-aware hot swap."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import replace
from repro.configs.registry import get_config
from repro.models.lm import init_lm
from repro.serve.kv_cache import BlockManager, PagedCachePool, blocks_for
from repro.serve.scheduler import Request, Scheduler

KEY = jax.random.PRNGKey(0)


def _f32_cfg(arch: str):
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    if cfg.moe is not None:   # dropless so train-mode forward matches
        cfg = replace(cfg, **{
            "moe.capacity_factor": float(cfg.moe.num_experts)})
    return cfg


def _prompts(cfg, n, max_len, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, max_len), 0, cfg.vocab_size), np.int32)


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,Hkv,D,bs,W", [
    (2, 4, 4, 32, 4, 3),     # MHA
    (3, 8, 2, 32, 8, 2),     # GQA 4:1
    (2, 4, 1, 64, 4, 4),     # MQA
])
@pytest.mark.parametrize("K", [1, 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel_matches_ref(B, H, Hkv, D, bs, W, K, dtype):
    """Pallas gather-decode/verify kernel (interpret) == jnp oracle
    over scattered pages, null-page rows included; K > 1 exercises the
    speculative-verify staircase (query t reaches lengths + t)."""
    from repro.kernels.ops import paged_attention
    from repro.kernels.ref import paged_attention_ref

    P = 9                      # pool pages (+1 null)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, K, H, D), dtype)
    if K == 1:                 # exercise the 3D single-token surface
        q = q[:, 0]
    kp = jax.random.normal(ks[1], (P + 1, bs, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (P + 1, bs, Hkv, D), dtype)
    rng = np.random.default_rng(0)
    # scattered, non-contiguous tables; trailing entries null
    tables = rng.permutation(P)[:B * W].reshape(B, W).astype(np.int32)
    lengths = rng.integers(1, W * bs - K + 2, size=(B,)).astype(np.int32)
    for b in range(B):
        used = blocks_for(int(lengths[b]) + K - 1, bs)
        tables[b, used:] = P    # null page
    out = paged_attention(q, kp, vp, jnp.asarray(tables),
                          jnp.asarray(lengths), interpret=True)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(tables),
                              jnp.asarray(lengths))
    assert out.shape == q.shape
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# paged vs dense scheduler parity (all attention families + hybrid/ssm)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", [
    "qwen3-0.6b",              # dense attention
    "deepseek-moe-16b",        # attention + MoE
    "jamba-1.5-large-398b",    # hybrid mamba/attention/moe
    "xlstm-125m",              # pure recurrent (slot-row passthrough)
])
def test_paged_vs_dense_scheduler_parity(arch):
    """The same trace served through layout='paged' and layout='dense'
    must generate identical tokens — the layout changes memory
    placement, not math."""
    cfg = _f32_cfg(arch)
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 3, 12)

    def serve(layout):
        s = Scheduler(cfg, params, num_slots=2, max_len=24, block_size=4,
                      layout=layout)
        for i in range(3):
            s.submit(Request(rid=i, prompt=toks[i, :5 + 3 * i], max_new=3))
        r = s.run(max_steps=200)
        assert len(r) == 3
        return r

    dense, paged = serve("dense"), serve("paged")
    for i in range(3):
        assert dense[i].tolist() == paged[i].tolist(), i


def test_chunked_prefill_matches_one_shot():
    """Chunked prefill (prefill_chunk=4) produces exactly the one-shot
    tokens; the chunk counter proves slices actually ran."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 2, 14)

    def serve(chunk):
        s = Scheduler(cfg, params, num_slots=2, max_len=32, block_size=4,
                      prefill_chunk=chunk)
        for i in range(2):
            s.submit(Request(rid=i, prompt=toks[i, :9 + 4 * i], max_new=4))
        r = s.run(max_steps=200)
        assert len(r) == 2
        return r, s

    one, s1 = serve(0)
    chunked, s2 = serve(4)
    assert s2.stats.prefill_chunks > s1.stats.prefill_chunks
    assert s2.stats.prefill_chunks >= 3    # 9 and 13 tokens in 4-chunks
    for i in range(2):
        assert one[i].tolist() == chunked[i].tolist(), i


# ---------------------------------------------------------------------------
# lazy page allocation / overflow
# ---------------------------------------------------------------------------


def test_block_manager_lazy_reservation():
    bm = BlockManager(num_blocks=8, block_size=4)
    bm.reserve("a", 20)                    # 5 blocks budgeted, 0 claimed
    assert bm.used_blocks == 0 and bm.pending_blocks == 5
    assert bm.available_blocks == 3
    assert bm.can_allocate(12) and not bm.can_allocate(13)
    got = bm.ensure("a", 6)                # materialize 2 pages
    assert len(got) == 2 and bm.used_blocks == 2 and bm.pending_blocks == 3
    assert bm.ensure("a", 6) == []         # idempotent
    with pytest.raises(RuntimeError, match="overflows"):
        bm.ensure("a", 24)                 # beyond the 5-block budget
    bm.extend("a", 24)                     # growing the budget is fine
    assert bm.used_blocks == 6
    released = bm.free("a")
    assert len(released) == 6 and bm.used_blocks == 0
    assert bm.pending_blocks == 0 and bm.available_blocks == 8


def test_page_overflow_allocation_during_decode():
    """Decode crossing a page boundary claims its next page lazily; an
    EOS-early request never touches the tail of its reservation."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 1, 6)
    sched = Scheduler(cfg, params, num_slots=1, max_len=32, block_size=4)
    # 6 prompt + 10 new = 16 tokens -> 4 pages reserved; prompt = 2
    sched.submit(Request(rid=0, prompt=toks[0], max_new=10))
    sched.step()                             # admit + prefill
    bm = sched.pool.blocks
    after_prefill = bm.used_blocks
    assert after_prefill == blocks_for(6, 4) == 2
    assert bm.pending_blocks == 2            # rest of the budget, unclaimed
    sched.run(max_steps=100)
    assert bm.allocs == 4                    # pages materialized one by one
    assert bm.used_blocks == 0               # all recycled

    # EOS-early: same request shape, stop after 2 generated tokens
    probe = Scheduler(cfg, params, num_slots=1, max_len=32, block_size=4)
    probe.submit(Request(rid=0, prompt=toks[0], max_new=10))
    gen = probe.run(max_steps=100)[0]
    eos = int(gen[1])
    s2 = Scheduler(cfg, params, num_slots=1, max_len=32, block_size=4)
    s2.submit(Request(rid=0, prompt=toks[0], max_new=10, eos_id=eos))
    s2.run(max_steps=100)
    assert s2.pool.blocks.allocs < 4         # tail pages never claimed


# ---------------------------------------------------------------------------
# prefix sharing refcounts
# ---------------------------------------------------------------------------


def test_prefix_sharing_refcounts_pool_level():
    cfg = _f32_cfg("qwen3-0.6b")
    pool = PagedCachePool(cfg, num_slots=3, num_pages=12, block_size=4)
    prompt = np.arange(11, dtype=np.int32)          # 2 full pages + tail
    _, shared = pool.admit("a", 16, prompt)
    assert shared == 0                               # nothing cached yet
    pool.ensure("a", 11)
    pool.register_prefix("a", prompt)
    a_pages = pool.blocks.table("a")[:2]

    # same prompt again -> both full pages mapped, refcount 2
    _, shared_b = pool.admit("b", 16, prompt)
    assert shared_b == 8
    assert pool.blocks.table("b")[:2] == a_pages
    assert all(pool.blocks.refcount(p) == 2 for p in a_pages)
    assert pool.prefix_hits == 1 and pool.prefix_shared_tokens == 8

    # a longer prompt sharing only the prefix chain
    prompt_c = np.concatenate([prompt[:8], np.arange(50, 58,
                                                     dtype=np.int32)])
    _, shared_c = pool.admit("c", 20, prompt_c.astype(np.int32))
    assert shared_c == 8
    assert all(pool.blocks.refcount(p) == 3 for p in a_pages)

    # the original owner dies first: shared pages must survive
    pool.release("a")
    assert all(pool.blocks.refcount(p) == 2 for p in a_pages)
    assert pool.find_shared_prefix(prompt)[1] == 8   # still resident
    pool.release("b")
    pool.release("c")
    assert all(pool.blocks.refcount(p) == 0 for p in a_pages)
    assert pool.blocks.used_blocks == 0
    assert pool.find_shared_prefix(prompt)[1] == 0   # evicted


def test_prefix_sharing_end_to_end_parity():
    """Requests sharing a system prefix decode the same tokens as
    fully-isolated requests, and the shared pages skip prefill work."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    rng = np.random.default_rng(5)
    sys_prefix = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate([sys_prefix, rng.integers(
        0, cfg.vocab_size, 3 + i).astype(np.int32)]) for i in range(3)]

    def serve(sharing):
        s = Scheduler(cfg, params, num_slots=3, max_len=32, block_size=4,
                      prefix_sharing=sharing)
        for i, p in enumerate(prompts):
            s.submit(Request(rid=i, prompt=p, max_new=4))
        r = s.run(max_steps=200)
        assert len(r) == 3
        return r, s

    iso, s_iso = serve(False)
    shr, s_shr = serve(True)
    assert s_iso.pool.prefix_hits == 0
    assert s_shr.pool.prefix_hits >= 1
    assert s_shr.pool.prefix_shared_tokens >= 8
    assert s_shr.stats.prefill_tokens < s_iso.stats.prefill_tokens
    for i in range(3):
        assert iso[i].tolist() == shr[i].tolist(), i


# ---------------------------------------------------------------------------
# drain-aware hot swap
# ---------------------------------------------------------------------------


class _ArmedRegistry:
    """refresh() reports a new winner exactly once, when armed."""

    def __init__(self):
        self.params = None
        self.armed_params = None

    def refresh(self):
        if self.armed_params is not None:
            self.params = self.armed_params
            self.armed_params = None
            return True
        return False


def test_hot_swap_invalidates_prefix_cache():
    """An immediate-mode weight swap must flush the prefix cache: a
    post-swap request with the same prompt may not attend over KV pages
    computed under the old weights."""
    cfg = _f32_cfg("qwen3-0.6b")
    p1, _ = init_lm(cfg, KEY)
    p2, _ = init_lm(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)

    sched = Scheduler(cfg, p1, num_slots=2, max_len=32, block_size=4)
    sched.submit(Request(rid="a", prompt=prompt, max_new=8))
    for _ in range(3):
        sched.step()        # "a" prefilled + registered, still decoding
    assert sched.pool.find_shared_prefix(prompt)[1] == 8
    sched.set_params(p2)
    assert sched.pool.find_shared_prefix(prompt)[1] == 0   # flushed
    sched.submit(Request(rid="b", prompt=prompt, max_new=4))
    out = sched.run(max_steps=200)
    assert sched.pool.prefix_hits == 0     # "b" never mapped old pages

    # "b"'s tokens must equal a fresh p2-only serve of the same prompt
    ref = Scheduler(cfg, p2, num_slots=1, max_len=32, block_size=4)
    ref.submit(Request(rid=0, prompt=prompt, max_new=4))
    assert out["b"].tolist() == ref.run(max_steps=100)[0].tolist()


def test_drain_swap_finishes_in_flight_on_old_weights():
    cfg = _f32_cfg("qwen3-0.6b")
    p1, _ = init_lm(cfg, KEY)
    p2, _ = init_lm(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(3)]

    def serve(swap_to, mode):
        reg = _ArmedRegistry()
        s = Scheduler(cfg, p1, num_slots=2, max_len=32, block_size=4,
                      registry=reg, watch_every=1, swap_mode=mode)
        s.submit(Request(rid=0, prompt=prompts[0], max_new=8))
        s.submit(Request(rid=1, prompt=prompts[1], max_new=8))
        for _ in range(3):
            s.step()
        reg.armed_params = swap_to
        if swap_to is not None:
            assert not s.draining
        s.submit(Request(rid=2, prompt=prompts[2], max_new=4))
        return s.run(max_steps=200), s

    base, _ = serve(None, "drain")
    drain, sd = serve(p2, "drain")
    imm, si = serve(p2, "immediate")
    # drain: in-flight requests 0/1 finish on the OLD weights
    assert drain[0].tolist() == base[0].tolist()
    assert drain[1].tolist() == base[1].tolist()
    # immediate: weights change under request 0 mid-stream
    assert imm[0].tolist() != base[0].tolist()
    # both modes: the late admission runs on the NEW weights
    assert drain[2].tolist() != base[2].tolist()
    assert drain[2].tolist() == imm[2].tolist()
    assert sd.stats.hot_swaps == 1 and si.stats.hot_swaps == 1
    assert not sd.draining


# ---------------------------------------------------------------------------
# surrogate staging/compute overlap
# ---------------------------------------------------------------------------


def test_surrogate_pipeline_overlaps_staging():
    """The double-buffered engine stages batch N+1 while batch N's
    device compute is in flight, without changing any result."""
    from repro.configs.icf_cyclegan import SMOKE
    from repro.models import icf_cyclegan as cg
    from repro.serve.surrogate import SurrogateEngine

    params, _ = cg.init_cyclegan(SMOKE, KEY)
    eng = SurrogateEngine(SMOKE, params, max_batch=8, bucket=4)
    rng = np.random.default_rng(0)
    xs = {i: rng.normal(size=(6, SMOKE.input_dim)).astype(np.float32)
          for i in range(5)}
    for i, x in xs.items():
        eng.submit(i, x)
    res = eng.run(max_steps=50)
    assert eng.stats.completed == 5
    assert eng.overlapped_stages >= 3   # 5 one-query batches, pipelined
    for i, x in xs.items():
        ref = np.asarray(cg.predict(params["gen"], jnp.asarray(x))
                         .astype(jnp.float32))
        np.testing.assert_allclose(res[i], ref, atol=1e-5)
