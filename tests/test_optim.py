"""Optimizer + compression tests, incl. hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.configs.base import OptimizerConfig
from repro.optim import compression as comp
from repro.optim import optimizers as opt_lib

KEY = jax.random.PRNGKey(3)


def test_adam_converges_on_quadratic():
    cfg = OptimizerConfig(name="adam", lr=0.1, warmup_steps=1)
    opt = opt_lib.make_optimizer(cfg)
    params = {"w": jnp.array([5.0, -3.0]),
              "nest": ({"b": jnp.array([2.0])},)}   # tuple internal node
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["nest"][0]["b"] ** 2)
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < 1e-3


def test_adafactor_converges_on_matrix_quadratic():
    cfg = OptimizerConfig(name="adafactor", lr=0.05)
    opt = opt_lib.make_optimizer(cfg)
    params = {"W": jnp.ones((4, 8)) * 3.0, "b": jnp.ones((8,))}
    state = opt.init(params)
    # factored second moment shapes
    assert state["vr"]["W"].shape == (4,)
    assert state["vc"]["W"].shape == (8,)
    loss = lambda p: jnp.sum(p["W"] ** 2) + jnp.sum(p["b"] ** 2)
    l0 = float(loss(params))
    for _ in range(400):
        params, state = opt.update(jax.grad(loss)(params), state, params)
    # update clipping (rms<=1) bounds steady-state error at ~lr per coord
    assert float(loss(params)) < 0.01 * l0


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, norm = opt_lib.clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
    assert float(opt_lib.global_norm(clipped)) == pytest.approx(1.0,
                                                                rel=1e-4)


def test_lr_schedule_warmup_and_cosine():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, schedule="cosine",
                          total_steps=110)
    assert float(opt_lib.lr_schedule(cfg, jnp.int32(0))) == 0.0
    assert float(opt_lib.lr_schedule(cfg, jnp.int32(10))) \
        == pytest.approx(1.0)
    assert float(opt_lib.lr_schedule(cfg, jnp.int32(110))) \
        == pytest.approx(0.0, abs=1e-6)


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=64))
@settings(max_examples=50, deadline=None)
def test_int8_quantization_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = comp.quantize_int8(x)
    err = jnp.max(jnp.abs(comp.dequantize_int8(q, scale) - x))
    # max error is half a quantization step
    assert float(err) <= float(scale) * 0.5 + 1e-6


def test_compressed_psum_pod_matches_mean():
    """2-pod compressed all-reduce == true mean within quantization err,
    and error feedback drives the *accumulated* bias to zero."""
    import subprocess, sys, os
    script = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.optim import compression as comp

mesh = Mesh(np.asarray(jax.devices()[:2]).reshape(2,), ("pod",))
g = jnp.stack([jnp.linspace(-1, 1, 64), jnp.linspace(2, -2, 64)])  # (2,64)
e = jnp.zeros((2, 64))

def body(gb, eb):
    # per-pod blocks are (1, 64)
    mean, err = comp.compressed_psum_pod({"g": gb[0]}, {"g": eb[0]},
                                         "pod", 2)
    return mean["g"][None], err["g"][None]

from repro.parallel.sharding import shard_map_compat
f = jax.jit(shard_map_compat(body, mesh=mesh,
            in_specs=(P("pod"), P("pod")),
            out_specs=(P("pod"), P("pod"))))
mean_ref = np.asarray(jnp.mean(g, axis=0))
out, err = f(g, e)
out = np.asarray(out)
# every pod holds the (quantized) mean
assert np.allclose(out[0], mean_ref, atol=0.03), np.abs(out[0]-mean_ref).max()
assert np.allclose(out[1], mean_ref, atol=0.03), np.abs(out[1]-mean_ref).max()
print("OK")
"""
    env = dict(os.environ)
    env.update({"XLA_FLAGS": "--xla_force_host_platform_device_count=2",
                "PYTHONPATH": "src"})
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


@given(st.integers(0, 6))
@settings(max_examples=7, deadline=None)
def test_error_feedback_preserves_signal_over_steps(seed):
    """Error-feedback quantization: the accumulated transmitted signal
    converges to the accumulated true signal (no systematic bias)."""
    rng = np.random.default_rng(seed)
    true_sum = np.zeros(32)
    sent_sum = np.zeros(32)
    e = jnp.zeros(32)
    for _ in range(50):
        g = jnp.asarray(rng.normal(0, 1, 32), jnp.float32)
        acc = g + e
        q, s = comp.quantize_int8(acc)
        sent = comp.dequantize_int8(q, s)
        e = acc - sent
        true_sum += np.asarray(g)
        sent_sum += np.asarray(sent)
    # residual bias is bounded by one quantization step, NOT O(steps)
    assert np.max(np.abs(true_sum - sent_sum)) < 0.2
