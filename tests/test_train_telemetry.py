"""Training-side telemetry: trace-span completeness per trainer,
Prometheus well-formedness, genealogy round-trips (checkpoint/resume,
rescale, failure recovery, torn tails), arena promotions joining the
training ancestry chain, the orchestrator's stats() timing fields, and
the online parallel-efficiency math."""
import json
import re
import urllib.request

import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.configs.icf_cyclegan import CycleGANConfig
from repro.core.population import TrainerFns
from repro.core.tournament import (DataPlan, TournamentConfig,
                                   TournamentOrchestrator)
from repro.data import jag
from repro.launch.lineage import ancestry, default_champion, summarize
from repro.train.steps import make_gan_steps
from repro.train.telemetry import (GenealogyLog, MetricsServer,
                                   TrainTelemetry, efficiency_snapshot,
                                   replay_genealogy, train_prometheus)

CCFG = CycleGANConfig(
    name="icf-cyclegan-test", image_size=8,
    fwd_hidden=(16, 16), inv_hidden=(16, 16), disc_hidden=(16,),
    enc_hidden=(32,), dec_hidden=(32,))


@pytest.fixture(scope="module")
def bundle_files(tmp_path_factory):
    root = tmp_path_factory.mktemp("teltourn_jag")
    return jag.write_bundles(str(root), num_samples=288,
                             samples_per_file=32, image_size=8, seed=0)


def _orch(files, k=4, telemetry=None, genealogy=None, **cfg_kw):
    fns = TrainerFns(*make_gan_steps(
        CCFG, OptimizerConfig(name="adam", lr=1e-3)))
    cfg = TournamentConfig(trainers=k, scope="generator", batch_size=16,
                           num_ranks=2, tournament_batches=1,
                           tournament_batch_size=32, seed=0, **cfg_kw)
    return TournamentOrchestrator(fns, DataPlan.jag_cyclegan(files), cfg,
                                  telemetry=telemetry, genealogy=genealogy)


# ---------------------------------------------------------------------------
# tentpole: per-trainer trace spans
# ---------------------------------------------------------------------------


def test_trace_spans_complete_per_trainer(bundle_files):
    tel = TrainTelemetry()
    orch = _orch(bundle_files, k=2, telemetry=tel)
    try:
        orch.run(rounds=2, steps_per_round=3)
    finally:
        orch.close()
    trace = tel.tracer.export()
    events = trace["traceEvents"]
    assert trace["otherData"]["dropped"] == 0
    # thread-name metadata: one orchestrator row + one row per trainer
    rows = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert {"orchestrator", "trainer 0", "trainer 1"} <= rows
    # every trainer emits the full span set each round
    by_tid = {}
    name_tid = {e["args"]["name"]: e["tid"] for e in events
                if e["ph"] == "M"}
    for e in events:
        if e["ph"] == "X":
            by_tid.setdefault(e["tid"], []).append(e)
    for t in (0, 1):
        names = {e["name"] for e in by_tid[name_tid[f"trainer {t}"]]}
        assert {"train_round", "step", "data_wait", "tournament_eval",
                "partner_exchange"} <= names, names
        steps = [e for e in by_tid[name_tid[f"trainer {t}"]]
                 if e["name"] == "step"]
        assert len(steps) == 6                    # 2 rounds x 3 steps
        assert all(e["dur"] >= 0 for e in steps)
    # the orchestrator row carries the tournament + phase accounting
    sched = {e["name"] for e in by_tid.get(name_tid["orchestrator"], [])}
    assert "tournament" in sched
    assert tel.phase_seconds["compute"] > 0
    assert set(tel.phase_seconds) >= {"compute", "data_wait",
                                      "tournament_eval",
                                      "partner_exchange"}


# ---------------------------------------------------------------------------
# tentpole: Prometheus exposition
# ---------------------------------------------------------------------------


def test_train_prometheus_well_formed(bundle_files):
    tel = TrainTelemetry()
    orch = _orch(bundle_files, k=2, telemetry=tel)
    try:
        orch.run(rounds=2, steps_per_round=2)
        text = train_prometheus(orch.stats(), tel.phase_seconds)
    finally:
        orch.close()
    helped, typed, seen = set(), set(), set()
    sample_re = re.compile(
        r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^{}]*\})? (-?[0-9.eE+-]+|NaN)$")
    for line in text.strip().splitlines():
        if line.startswith("# HELP "):
            helped.add(line.split()[2])
        elif line.startswith("# TYPE "):
            assert line.split()[3] in ("counter", "gauge")
            typed.add(line.split()[2])
        else:
            m = sample_re.match(line)
            assert m, f"malformed sample line: {line!r}"
            assert "NaN" not in line
            seen.add(m.group(1))
    # every sample's family is declared, prefix is the train namespace
    assert seen <= typed == helped
    assert all(n.startswith("repro_train_") for n in seen)
    # counters end in _total (exposition convention)
    for fam in ("repro_train_rounds_total", "repro_train_steps_total",
                "repro_train_tournament_exchange_bytes_total",
                "repro_train_data_wait_seconds_total",
                "repro_train_datastore_samples_fetched_total"):
        assert fam in seen, fam
    # per-trainer labelled families + online efficiency gauges
    assert 'repro_train_trainer_steps{trainer="1"}' in text
    assert 'repro_train_trainer_loss{trainer="0",metric=' in text
    assert "repro_train_speedup " in text
    assert "repro_train_efficiency " in text
    assert "repro_train_exchange_bandwidth_bytes_per_s " in text


def test_metrics_server_serves_exposition():
    srv = MetricsServer(port=0)
    try:
        srv.update("# HELP repro_train_rounds_total r\n"
                   "# TYPE repro_train_rounds_total counter\n"
                   "repro_train_rounds_total 3\n")
        with urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/metrics") as r:
            assert r.headers["Content-Type"].startswith("text/plain")
            body = r.read().decode()
        assert "repro_train_rounds_total 3" in body
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# tentpole: genealogy + lineage
# ---------------------------------------------------------------------------


def test_genealogy_roundtrip_checkpoint_resume(bundle_files, tmp_path):
    ck = str(tmp_path / "ck")
    gpath = str(tmp_path / "genealogy.jsonl")
    orch = _orch(bundle_files, ckpt_dir=ck, genealogy=GenealogyLog(gpath))
    try:
        orch.run(rounds=2, steps_per_round=2, ckpt_every=1)
    finally:
        orch.close()
        orch.genealogy.close()

    orch2 = _orch(bundle_files, ckpt_dir=ck,
                  genealogy=GenealogyLog(gpath))
    try:
        assert orch2.maybe_resume()
        orch2.run(rounds=1, steps_per_round=2)
    finally:
        orch2.close()
        orch2.genealogy.close()

    recs = replay_genealogy(gpath)
    kinds = [r["t"] for r in recs]
    assert kinds.count("init") == 2               # one per process
    assert "checkpoint" in kinds and "resume" in kinds
    # matches and rounds span the resume: rounds 0,1 then 2
    rounds = [r["round"] for r in recs if r["t"] == "round"]
    assert rounds == [0, 1, 2]
    assert all(len([r for r in recs
                    if r["t"] == "match" and r["round"] == i]) == 4
               for i in rounds)
    # ancestry of the final best trainer walks back to an init root
    champ = default_champion(recs)
    chain = ancestry(recs, champ)
    assert chain and chain[0]["t"] == "init"
    summ = summarize(recs)
    assert summ["rounds"] == 3 and summ["trainers"] == 4


def test_genealogy_rescale_and_recover(bundle_files, tmp_path):
    gpath = str(tmp_path / "genealogy.jsonl")
    orch = _orch(bundle_files, k=2, genealogy=GenealogyLog(gpath))
    try:
        orch.run(rounds=1, steps_per_round=2)
        orch.rescale(4)
        orch.fail(1)
        orch.tournament()
        orch.recover(1)
    finally:
        orch.close()
        orch.genealogy.close()
    recs = replay_genealogy(gpath)
    resc = [r for r in recs if r["t"] == "rescale"]
    assert len(resc) == 1
    assert resc[0]["from_k"] == 2 and resc[0]["to_k"] == 4
    assert resc[0]["cloned"] == [2, 3]
    assert resc[0]["clone_src"] in (0, 1)
    assert [r for r in recs if r["t"] == "fail"][0]["trainer"] == 1
    rec = [r for r in recs if r["t"] == "recover"][0]
    assert rec["trainer"] == 1 and rec["cloned_from"] is not None
    # a grown trainer's ancestry passes through the rescale clone edge
    chain = ancestry(recs, "trainer_3")
    assert any(r["t"] == "rescale" for r in chain)
    assert chain[0]["t"] == "init"


def test_genealogy_torn_tail_replay(tmp_path):
    gpath = str(tmp_path / "g.jsonl")
    g = GenealogyLog(gpath)
    g.append("init", trainers=2, seed=0)
    g.append("match", round=0, trainer=0, partner=1, adopted=True)
    g.close()
    with open(gpath, "a") as f:                    # torn final record
        f.write('{"t": "round", "round": 0, "best')
    recs = replay_genealogy(gpath)
    assert [r["t"] for r in recs] == ["init", "match"]
    # appending after a crash keeps the readable prefix usable
    assert replay_genealogy(str(tmp_path / "missing.jsonl")) == []


def test_arena_promotion_joins_training_ancestry(bundle_files, tmp_path):
    from repro.serve.arena import Arena, ArenaConfig
    from repro.serve.registry import population_steps

    pop_dir = str(tmp_path / "pop")
    gpath = str(tmp_path / "pop" / "genealogy.jsonl")
    orch = _orch(bundle_files, k=2, ckpt_dir=pop_dir,
                 genealogy=GenealogyLog(gpath))
    try:
        orch.run(rounds=1, steps_per_round=2, ckpt_every=1)
        like = orch.population.trainers[0].params
    finally:
        orch.close()
        orch.genealogy.close()
    assert population_steps(pop_dir) == [1]

    arena = Arena.from_population(pop_dir, like, ArenaConfig())
    try:
        assert arena.genealogy is not None         # rank-0 hookup
        loser = arena.champion
        winner = arena.challengers[0]
        arena.forced = winner
        assert arena.decide(step=7) == winner
        arena.promote(winner, step=7)
    finally:
        arena.close()

    recs = replay_genealogy(gpath)
    promo = [r for r in recs if r["t"] == "promotion"]
    assert len(promo) == 1
    assert promo[0]["winner"] == winner and promo[0]["loser"] == loser
    assert promo[0]["generation"] == 1
    # one chain: the promoted champion's ancestry spans arena + training
    chain = ancestry(recs, default_champion(recs))
    assert chain[-1]["t"] == "promotion"
    assert chain[0]["t"] == "init"
    # a follower rank never writes genealogy
    arena2 = Arena.from_population(pop_dir, like, ArenaConfig(), rank=1)
    try:
        assert arena2.genealogy is None
    finally:
        arena2.close()
    assert len([r for r in replay_genealogy(gpath)
                if r["t"] == "promotion"]) == 1


# ---------------------------------------------------------------------------
# satellite: stats() timing/event gaps
# ---------------------------------------------------------------------------


def test_stats_carries_timings_and_events(bundle_files):
    orch = _orch(bundle_files, k=2, telemetry=TrainTelemetry())
    try:
        orch.run(rounds=2, steps_per_round=2)
        st = orch.stats()
        assert st["round_wall_seconds"] > 0
        assert st["last_round_seconds"] > 0
        assert st["tournament_seconds"] > 0
        assert st["train_seconds"] > 0
        assert st["data_wait_seconds"] >= 0
        assert st["steps"] == 8
        assert st["events"] == {"rescales": 0, "failures": 0,
                                "recoveries": 0, "checkpoints": 0,
                                "restores": 0}
        eff = st["efficiency"]
        assert eff["trainers"] == 2
        assert eff["speedup"] > 0 and eff["parallel_samples_per_s"] > 0
        per = st["per_trainer"]
        assert all("data_wait_seconds" in d and "train_seconds" in d
                   and "tournament_metric" in d for d in per)
        orch.rescale(4)
        orch.fail(1)
        orch.recover(1)
        ev = orch.stats()["events"]
        assert ev["rescales"] == 1
        assert ev["failures"] == 1 and ev["recoveries"] == 1
    finally:
        orch.close()


# ---------------------------------------------------------------------------
# satellite: online parallel-efficiency math
# ---------------------------------------------------------------------------


def test_efficiency_snapshot_math():
    per = [{"steps": 100, "train_seconds": 10.0, "data_wait_seconds": 1.0}
           for _ in range(4)]
    eff = efficiency_snapshot(per, batch_size=32, tournament_seconds=2.0,
                              round_wall_seconds=42.0)
    assert eff["trainers"] == 4
    assert eff["samples"] == 4 * 100 * 32
    assert eff["single_trainer_samples_per_s"] == pytest.approx(320.0)
    # parallel time = slowest trainer + tournament (trainers concurrent)
    assert eff["parallel_samples_per_s"] == pytest.approx(12800 / 12.0)
    assert eff["speedup"] == pytest.approx((12800 / 12.0) / 320.0)
    assert eff["efficiency"] == pytest.approx(eff["speedup"] / 4)
    assert eff["data_wait_seconds"] == pytest.approx(4.0)
    with_flops = efficiency_snapshot(
        per, 32, 2.0, 42.0, flops_per_step=1e6)
    assert with_flops["model_flops_per_s"] == pytest.approx(400e6 / 12.0)
    # dead/idle trainers are excluded from the single-trainer baseline
    idle = per + [{"steps": 0, "train_seconds": 0.0,
                   "data_wait_seconds": 0.0}]
    eff2 = efficiency_snapshot(idle, 32, 2.0, 42.0)
    assert eff2["single_trainer_samples_per_s"] == pytest.approx(320.0)


def test_genealogy_match_records_carry_seed_and_metrics(bundle_files,
                                                        tmp_path):
    gpath = str(tmp_path / "g.jsonl")
    orch = _orch(bundle_files, k=2, genealogy=GenealogyLog(gpath))
    try:
        orch.run(rounds=1, steps_per_round=1)
    finally:
        orch.close()
        orch.genealogy.close()
    matches = [r for r in replay_genealogy(gpath) if r["t"] == "match"]
    assert len(matches) == 2
    for m in matches:
        assert {"round", "trainer", "partner", "m_local", "m_other",
                "winner", "adopted", "seed"} <= set(m)
        assert np.isfinite(m["m_local"]) and np.isfinite(m["m_other"])
        assert m["winner"] == (m["partner"] if m["adopted"]
                               else m["trainer"])
