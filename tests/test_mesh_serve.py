"""Mesh-serving tests: 1-vs-8-emulated-device token parity across
layouts/families, host-0 admission broadcast determinism (follower
replay over the wire encoding), drain-mode hot swap on the mesh, the
shard_map paged-gather dispatch vs the global oracle, and the
satellite serving features (fused draft round, per-row speculative
depth, draft-arch compatibility).

Multi-device cases run in subprocesses (the in-process jax backend is
already initialized with 1 CPU device) with
``--xla_force_host_platform_device_count=8`` — the same emulation the
CI mesh job uses."""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import replace
from repro.configs.registry import get_config
from repro.models.lm import init_lm
from repro.serve.scheduler import Request, Scheduler

KEY = jax.random.PRNGKey(0)


def _f32_cfg(arch: str):
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    if cfg.moe is not None:
        cfg = replace(cfg, **{
            "moe.capacity_factor": float(cfg.moe.num_experts)})
    return cfg


def _run_mesh_script(script: str, devices: int = 8) -> None:
    env = dict(os.environ)
    env.update({
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "JAX_PLATFORMS": "cpu",
        "PYTHONPATH": "src",
    })
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]


_PRELUDE = r"""
import dataclasses
import jax, numpy as np
from repro.configs.base import replace
from repro.configs.registry import get_config
from repro.models.lm import init_lm
from repro.serve.scheduler import Request, Scheduler
from repro.serve.mesh import MeshScheduler, StepPlan

def f32_cfg(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              dtype="float32")
    if cfg.moe is not None:
        cfg = replace(cfg, **{
            "moe.capacity_factor": float(cfg.moe.num_experts)})
    return cfg

def trace(cfg, n=5, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, 5 + 3 * i).astype(np.int32)
            for i in range(n)]

def serve(cls, cfg, params, prompts, layout="paged", **kw):
    s = cls(cfg, params, num_slots=4, max_len=40, block_size=4,
            layout=layout, **kw)
    for i, p in enumerate(prompts):
        s.submit(Request(rid=i, prompt=p, max_new=6))
    return s.run(max_steps=400), s
"""


# ---------------------------------------------------------------------------
# token parity: 1 device vs the 8-emulated-device mesh
# ---------------------------------------------------------------------------


def test_mesh_token_parity_attention_paged_and_dense():
    """qwen3 on a 4x2 (data, model) mesh: paged AND dense layouts are
    token-identical to the single-device scheduler on the same trace."""
    _run_mesh_script(_PRELUDE + r"""
cfg = f32_cfg("qwen3-0.6b")
params, _ = init_lm(cfg, jax.random.PRNGKey(0))
prompts = trace(cfg)
for layout in ("paged", "dense"):
    base, _ = serve(Scheduler, cfg, params, prompts, layout=layout)
    got, s = serve(MeshScheduler, cfg, params, prompts, layout=layout,
                   mesh_shape=(4, 2))
    assert s.pool.num_slots == 4 and s.data_shards == 4
    for i in base:
        assert base[i].tolist() == got[i].tolist(), (layout, i)
print("OK")
""")


def test_mesh_token_parity_hybrid():
    """jamba (mamba/attention/moe hybrid) on a 2x2 mesh: the paged
    pools shard over data, the recurrent state rows shard over data,
    tokens unchanged."""
    _run_mesh_script(_PRELUDE + r"""
cfg = f32_cfg("jamba-1.5-large-398b")
params, _ = init_lm(cfg, jax.random.PRNGKey(0))
prompts = trace(cfg, n=4)
base, _ = serve(Scheduler, cfg, params, prompts)
got, _ = serve(MeshScheduler, cfg, params, prompts, mesh_shape=(2, 2))
for i in base:
    assert base[i].tolist() == got[i].tolist(), i
print("OK")
""")


def test_mesh_spec_decode_token_identity():
    """Speculative decoding ON the mesh (fused draft, temperature > 0)
    emits exactly the single-device target-only tokens."""
    _run_mesh_script(_PRELUDE + r"""
cfg = f32_cfg("qwen3-0.6b")
params, _ = init_lm(cfg, jax.random.PRNGKey(0))
draft, _ = init_lm(cfg, jax.random.PRNGKey(7))
rng = np.random.default_rng(3)
prompts = [rng.integers(0, cfg.vocab_size, 6 + 2 * i).astype(np.int32)
           for i in range(4)]

def spec_serve(cls, dp, k, **kw):
    s = cls(cfg, params, num_slots=4, max_len=40, block_size=4,
            draft_params=dp, spec_tokens=k, **kw)
    for i, p in enumerate(prompts):
        s.submit(Request(rid=i, prompt=p, max_new=6, temperature=0.7,
                         seed=11 + i))
    return s.run(max_steps=400), s

base, _ = spec_serve(Scheduler, None, 0)
got, sm = spec_serve(MeshScheduler, draft, 3, mesh_shape=(4, 2))
for i in base:
    assert base[i].tolist() == got[i].tolist(), i
d = sm.stats.as_dict()
assert d["spec_rounds"] > 0
# fused drafting: ONE draft dispatch per verify round (replays extra)
assert d["spec_draft_steps"] == d["spec_rounds"]
print("OK")
""")


# ---------------------------------------------------------------------------
# host-0 broadcast determinism
# ---------------------------------------------------------------------------


def test_mesh_follower_replay_determinism():
    """host 0's StepPlans, round-tripped through the wire encoding,
    drive a follower replica to an IDENTICAL end state (results + pool
    accounting) — the admission-broadcast contract."""
    _run_mesh_script(_PRELUDE + r"""
cfg = f32_cfg("qwen3-0.6b")
params, _ = init_lm(cfg, jax.random.PRNGKey(0))
prompts = trace(cfg, n=4, seed=5)

def mk():
    s = MeshScheduler(cfg, params, num_slots=4, max_len=32,
                      block_size=4, mesh_shape=(4, 2))
    for i, p in enumerate(prompts):
        s.submit(Request(rid=i, prompt=p, max_new=5))
    return s

host0, follower = mk(), mk()
nadmit, steps = 0, 0
while (host0.queue or host0.active or host0.prefilling) and steps < 200:
    plan = host0.step()
    follower.step(plan=StepPlan.decode(plan.encode()))   # the wire
    nadmit += len(plan.admits)
    steps += 1
assert nadmit == 4
assert host0.results.keys() == follower.results.keys()
for k in host0.results:
    assert host0.results[k].tolist() == follower.results[k].tolist()
assert host0.pool.as_dict() == follower.pool.as_dict()
assert host0._index.tolist() == follower._index.tolist()
print("OK")
""")


def test_mesh_hot_swap_drain():
    """Drain-mode hot swap on the mesh: host 0 finds the new winner,
    the broadcast winner step swaps every replica AFTER in-flight
    requests finish on the old weights; followers load the exact
    broadcast step."""
    _run_mesh_script(_PRELUDE + r"""
import os, tempfile
from repro.checkpoint import ckpt
from repro.serve.registry import ModelRegistry

cfg = f32_cfg("qwen3-0.6b")
p1, _ = init_lm(cfg, jax.random.PRNGKey(0))
p2, _ = init_lm(cfg, jax.random.PRNGKey(7))
rng = np.random.default_rng(1)
prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
           for i in range(3)]
tmp = tempfile.mkdtemp()
ckpt.save(os.path.join(tmp, "winner_step_1.ckpt"), {"params": p1},
          metadata={"step": 1})

def mk():
    reg = ModelRegistry(tmp, p1)
    s = MeshScheduler(cfg, reg.load(), mesh_shape=(4, 2), num_slots=4,
                      max_len=32, block_size=4, registry=reg,
                      watch_every=1, swap_mode="drain")
    return s

host0, follower = mk(), mk()
for sched in (host0, follower):
    sched.submit(Request(rid=0, prompt=prompts[0], max_new=8))
    sched.submit(Request(rid=1, prompt=prompts[1], max_new=8))
plans = [host0.step() for _ in range(3)]
for p in plans:
    follower.step(plan=StepPlan.decode(p.encode()))
# the new winner appears mid-flight
ckpt.save(os.path.join(tmp, "winner_step_2.ckpt"), {"params": p2},
          metadata={"step": 2})
for sched in (host0, follower):
    sched.submit(Request(rid=2, prompt=prompts[2], max_new=4))
steps = 0
while (host0.queue or host0.active or host0.prefilling) and steps < 300:
    plan = host0.step()
    follower.step(plan=StepPlan.decode(plan.encode()))
    steps += 1
assert host0.stats.hot_swaps == 1 and follower.stats.hot_swaps == 1
assert host0.registry.step == 2 and follower.registry.step == 2
for k in host0.results:
    assert host0.results[k].tolist() == follower.results[k].tolist()

# drain semantics preserved on the mesh: in-flight rids 0/1 finished on
# the OLD weights (== a p1-only serve), rid 2 ran on the new winner
def ref_serve(params, rids_prompts, max_new):
    s = MeshScheduler(cfg, params, mesh_shape=(4, 2), num_slots=4,
                      max_len=32, block_size=4)
    for rid, p in rids_prompts:
        s.submit(Request(rid=rid, prompt=p, max_new=max_new))
    return s.run(max_steps=300)

ref = ref_serve(p1, [(0, prompts[0]), (1, prompts[1])], 8)
assert host0.results[0].tolist() == ref[0].tolist()
assert host0.results[1].tolist() == ref[1].tolist()
# rid 2 decoded alone post-drain: must equal a p2-only serve
ref2 = ref_serve(p2, [(2, prompts[2])], 4)
assert host0.results[2].tolist() == ref2[2].tolist()
print("OK")
""")


# ---------------------------------------------------------------------------
# sharded paged-gather dispatch vs the global oracle
# ---------------------------------------------------------------------------


def test_sharded_paged_gather_matches_oracle():
    """ops.paged_attention under a (data, model) sharding context ==
    ref.paged_attention_ref on the unsharded global pool, for K = 1 and
    a K = 3 verify staircase, GQA heads, inside scan-under-jit — and
    no page moves across `data` (each row's tables stay in its shard)."""
    _run_mesh_script(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.kernels import ops, ref
from repro.parallel.sharding import use_sharding

mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
D_, bs, Hkv, H, hd = 4, 4, 2, 4, 8
pps = 3
P_tot = D_ * (pps + 1)
B, W = 8, 3
rng = np.random.default_rng(0)
k_pages = rng.normal(size=(P_tot, bs, Hkv, hd)).astype(np.float32)
v_pages = rng.normal(size=(P_tot, bs, Hkv, hd)).astype(np.float32)
tables = np.zeros((B, W), np.int32)
lengths = np.zeros((B,), np.int32)
for b in range(B):
    s = b // (B // D_)
    base = s * (pps + 1)
    tables[b] = [base + (b % pps), base + ((b + 1) % pps),
                 base + pps]                      # 2 real pages + null
    lengths[b] = 1 + b % (2 * bs - 3)
kp = jax.device_put(jnp.asarray(k_pages),
                    NamedSharding(mesh, P("data", None, "model", None)))
vp = jax.device_put(jnp.asarray(v_pages),
                    NamedSharding(mesh, P("data", None, "model", None)))
for K in (1, 3):
    q = rng.normal(size=(B, K, H, hd)).astype(np.float32)
    if K == 1:
        qq = q[:, 0]
    else:
        qq = q
    want = ref.paged_attention_ref(
        jnp.asarray(qq), jnp.asarray(k_pages), jnp.asarray(v_pages),
        jnp.asarray(tables), jnp.asarray(lengths))

    def f(q, kp, vp, t, l):
        def body(c, _):
            o = ops.paged_attention(q, kp, vp, t, l)
            return c, o
        _, os_ = jax.lax.scan(body, 0.0, jnp.arange(2))
        return os_[0]

    with use_sharding(mesh):
        got = jax.jit(f)(jnp.asarray(qq), kp, vp, jnp.asarray(tables),
                         jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
print("OK")
""")


def test_engine_mesh_generate_parity():
    """Engine.generate over the mesh == Engine.generate single-device
    (the dry-run decode cell's weights-stationary layout, live)."""
    _run_mesh_script(r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config
from repro.models.lm import init_lm
from repro.serve.engine import Engine
from repro.serve.mesh import make_serve_mesh

cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                          dtype="float32")
params, _ = init_lm(cfg, jax.random.PRNGKey(0))
toks = jnp.asarray(np.random.default_rng(0).integers(
    0, cfg.vocab_size, (8, 6)).astype(np.int32))
base = Engine(cfg, params, max_len=32).generate(toks, 5)
mesh = make_serve_mesh(4, 2)
got = Engine(cfg, params, max_len=32, mesh=mesh).generate(toks, 5)
assert np.asarray(base).tolist() == np.asarray(got).tolist()
print("OK")
""")


# ---------------------------------------------------------------------------
# satellites: fused draft round, per-row depth, draft compatibility
# ---------------------------------------------------------------------------


def _spec_serve(cfg, params, prompts, draft=None, k=0, **kw):
    s = Scheduler(cfg, params, num_slots=2, max_len=32, block_size=4,
                  draft_params=draft, spec_tokens=k, **kw)
    for i, p in enumerate(prompts):
        s.submit(Request(rid=i, prompt=p, max_new=6))
    return s.run(max_steps=300), s


def test_fused_draft_round_is_two_dispatches():
    """The fused draft step collapses a round from K+1 draft dispatches
    to ONE (plus the verify): tokens identical either way, on dense and
    hybrid (rollback) stacks."""
    for arch in ("qwen3-0.6b", "jamba-1.5-large-398b"):
        cfg = _f32_cfg(arch)
        params, _ = init_lm(cfg, KEY)
        prompts = [_p for _p in np.random.default_rng(2).integers(
            0, cfg.vocab_size, (2, 7)).astype(np.int32)]
        base, _ = _spec_serve(cfg, params, prompts)
        fused, sf = _spec_serve(cfg, params, prompts, draft=params, k=3)
        seq, ss = _spec_serve(cfg, params, prompts, draft=params, k=3,
                              spec_fused=False)
        for i in base:
            assert base[i].tolist() == fused[i].tolist(), (arch, i)
            assert base[i].tolist() == seq[i].tolist(), (arch, i)
        df, ds = sf.stats.as_dict(), ss.stats.as_dict()
        assert df["spec_rounds"] == ds["spec_rounds"]
        # fused: one draft dispatch per round; sequential: K+1 per round
        assert df["spec_draft_steps"] == df["spec_rounds"]
        assert ds["spec_draft_steps"] > 3 * ds["spec_rounds"]


def test_fused_draft_temperature_identity_with_divergent_drafter():
    """At temperature > 0 the host resample can diverge from the
    on-device greedy feed — the drafter-cache repair keeps the output
    token-identical to target-only decoding."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    draft, _ = init_lm(cfg, jax.random.PRNGKey(11))
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size), np.int32)

    def serve(dp, k):
        s = Scheduler(cfg, params, num_slots=2, max_len=28, block_size=4,
                      draft_params=dp, spec_tokens=k)
        for i in range(2):
            s.submit(Request(rid=i, prompt=toks[i], max_new=6,
                             temperature=0.9, seed=42 + i))
        return s.run(max_steps=300)

    assert {k: v.tolist() for k, v in serve(None, 0).items()} \
        == {k: v.tolist() for k, v in serve(draft, 3).items()}


def test_spec_adapt_per_row_depth():
    """--spec-adapt: a disagreeing drafter drives a row's K down to 1,
    a perfect (self) drafter keeps it at the cap; tokens stay identical
    to target-only decoding either way."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    bad_draft, _ = init_lm(cfg, jax.random.PRNGKey(11))
    prompts = [p for p in np.random.default_rng(4).integers(
        0, cfg.vocab_size, (2, 6)).astype(np.int32)]

    base, _ = _spec_serve(cfg, params, prompts)
    good, sg = _spec_serve(cfg, params, prompts, draft=params, k=4,
                           spec_adapt=True)
    bad, sb = _spec_serve(cfg, params, prompts, draft=bad_draft, k=4,
                          spec_adapt=True)
    for i in base:
        assert base[i].tolist() == good[i].tolist(), i
        assert base[i].tolist() == bad[i].tolist(), i
    assert set(sg.spec_k_by_rid) == {0, 1}
    assert set(sb.spec_k_by_rid) == {0, 1}
    # near-zero accept: every row's depth collapses toward 1
    assert all(k <= 2 for k in sb.spec_k_by_rid.values())
    assert sb.stats.as_dict()["spec_k_mean"] \
        < sg.stats.as_dict()["spec_k_mean"]
    # a perfect drafter holds (or regrows to) the cap
    assert max(sg.spec_k_by_rid.values()) >= 3


def test_draft_compat_vocab_mismatch_is_a_clear_error():
    """A drafter with a different vocab must fail LOUDLY, at setup."""
    from repro.serve.registry import check_draft_compat, load_draft

    cfg = _f32_cfg("qwen3-0.6b")
    bad = dataclasses.replace(cfg, vocab_size=cfg.vocab_size * 2)
    with pytest.raises(ValueError, match="tokenizer"):
        check_draft_compat(cfg, bad)
    params, _ = init_lm(cfg, KEY)
    with pytest.raises(ValueError, match="tokenizer"):
        Scheduler(cfg, params, num_slots=1, max_len=16,
                  draft_params=params, spec_tokens=2, draft_cfg=bad)
    # load-time check: a checkpoint whose embedding disagrees with the
    # target's vocab is rejected with a clear message
    import tempfile

    from repro.checkpoint import ckpt
    small, _ = init_lm(bad, KEY)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "draft.ckpt")
        ckpt.save(path, {"params": small}, metadata={})
        with pytest.raises(ValueError, match="tokenizer-incompatible"):
            load_draft(path, small, expect_vocab=cfg.vocab_size)


def test_draft_arch_smaller_model_serves():
    """Per-session configs: a drafter with FEWER layers/heads than the
    target proposes tokens through its own pool; output still token-
    identical to target-only decoding."""
    cfg = _f32_cfg("qwen3-0.6b")
    small = dataclasses.replace(cfg, num_layers=1, name="qwen3-draft")
    params, _ = init_lm(cfg, KEY)
    dparams, _ = init_lm(small, jax.random.PRNGKey(3))
    prompts = [p for p in np.random.default_rng(6).integers(
        0, cfg.vocab_size, (2, 6)).astype(np.int32)]
    base, _ = _spec_serve(cfg, params, prompts)
    spec, ss = _spec_serve(cfg, params, prompts, draft=dparams, k=3,
                           draft_cfg=small)
    for i in base:
        assert base[i].tolist() == spec[i].tolist(), i
    assert ss.stats.as_dict()["spec_rounds"] > 0


def test_parse_mesh_specs():
    from repro.serve.mesh import parse_mesh
    assert parse_mesh("4,2") == (4, 2)
    assert parse_mesh("8") == (8, 1)
    assert parse_mesh("data=2,model=4") == (2, 4)
    with pytest.raises(ValueError):
        parse_mesh("1,2,3")


def test_serve_cache_specs_resolve_mesh_placement():
    """serve_cache_specs + the serve rules resolve every cache leaf's
    mesh placement WITHOUT allocating: paged pools shard their page dim
    over `data`, recurrent state rows shard their batch dim over
    `data` — the layout the live mesh places the real pools with."""
    _run_mesh_script(r"""
import jax, numpy as np
from jax.sharding import Mesh
from repro.configs.registry import get_config
from repro.launch.specs import serve_cache_specs
from repro.parallel.sharding import tree_shardings
from repro.serve.mesh import MESH_SERVE_RULES

mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
for arch in ("qwen3-0.6b", "jamba-1.5-large-398b"):
    cfg = get_config(arch, smoke=True)
    shapes, axes = serve_cache_specs(cfg, num_slots=8, num_pages=15,
                                     block_size=4)
    sh = tree_shardings(mesh, axes, shapes, **MESH_SERVE_RULES)
    leaves = list(zip(jax.tree.leaves(shapes), jax.tree.leaves(sh)))
    assert leaves
    data_sharded = 0
    for sds, spec in leaves:
        ss = spec.shard_shape(sds.shape)
        assert all(a % b == 0 for a, b in zip(sds.shape, ss))
        if ss != sds.shape:
            data_sharded += 1
    assert data_sharded > 0, arch      # something actually sharded
print("OK")
""")
