"""Serving subsystem tests: decode-path parity, the continuous-batching
scheduler (slot reuse, EOS completion, token-budget admission,
hot-swap), the block/paged cache manager, winner export/registry, and
the ltfb -> serve CLI integration path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import replace
from repro.configs.registry import get_config
from repro.models.lm import init_lm, lm_forward
from repro.serve.kv_cache import BlockManager, CachePool, blocks_for
from repro.serve.scheduler import Request, Scheduler

KEY = jax.random.PRNGKey(0)


def _f32_cfg(arch: str):
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    if cfg.moe is not None:   # dropless so train-mode forward matches
        cfg = replace(cfg, **{
            "moe.capacity_factor": float(cfg.moe.num_experts)})
    return cfg


def _prompts(cfg, n, max_len, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, max_len), 0, cfg.vocab_size), np.int32)


def _assert_greedy_parity(cfg, params, sched, reqs):
    """Every generated token must equal the argmax of a full-context
    forward over the sequence so far (prefill/decode parity)."""
    for r in reqs:
        seq = sched.full_sequence(r)
        P = r.prompt_len
        for i in range(len(sched.results[r.rid])):
            lg, _ = lm_forward(params, cfg,
                               {"tokens": jnp.asarray(seq[None, :P + i])})
            assert int(jnp.argmax(lg[0, -1])) == int(seq[P + i]), \
                (r.rid, i)


# ---------------------------------------------------------------------------
# block manager / cache pool
# ---------------------------------------------------------------------------


def test_block_manager_accounting():
    bm = BlockManager(num_blocks=8, block_size=4)
    assert blocks_for(1, 4) == 1 and blocks_for(4, 4) == 1 \
        and blocks_for(5, 4) == 2
    bm.allocate("a", 10)          # 3 blocks
    assert bm.used_blocks == 3 and bm.free_blocks == 5
    assert bm.can_allocate(20) and not bm.can_allocate(21)
    bm.extend("a", 13)            # grow to 4 blocks
    assert bm.used_blocks == 4 and bm.high_water == 4
    with pytest.raises(ValueError):
        bm.allocate("a", 4)       # double-alloc
    with pytest.raises(RuntimeError):
        bm.allocate("b", 100)     # over budget
    assert len(bm.free("a")) == 4      # all refcounts hit zero
    assert bm.used_blocks == 0 and bm.high_water == 4
    assert bm.allocs == 4 and bm.frees == 4


def test_cache_pool_slot_lifecycle():
    cfg = _f32_cfg("qwen3-0.6b")
    pool = CachePool(cfg, num_slots=2, max_len=16, block_size=4)
    assert pool.can_admit(16) and not pool.can_admit(17)
    s0 = pool.admit("r0", 12)
    s1 = pool.admit("r1", 12)
    assert {s0, s1} == {0, 1} and pool.free_slots == 0
    assert not pool.can_admit(4)            # no slot left
    pool.release("r0")
    assert pool.free_slots == 1 and pool.blocks.used_blocks == 3
    assert pool.admit("r2", 8) == s0        # slot reuse
    pool.release("r1")
    pool.release("r2")
    assert pool.free_slots == 2 and pool.blocks.used_blocks == 0


# ---------------------------------------------------------------------------
# scheduler correctness
# ---------------------------------------------------------------------------


def test_scheduler_greedy_parity_and_slot_reuse():
    """5 mixed-length requests over 2 slots: all complete, every token
    matches full-context argmax, slots + pages fully recycled."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 5, 16)
    sched = Scheduler(cfg, params, num_slots=2, max_len=32, block_size=4)
    reqs = [Request(rid=i, prompt=toks[i, :4 + 3 * (i % 3)], max_new=5)
            for i in range(5)]
    for r in reqs:
        sched.submit(r)
    res = sched.run(max_steps=200)
    assert len(res) == 5
    assert sched.stats.completed == 5
    _assert_greedy_parity(cfg, params, sched, reqs)
    # everything returned to the pool
    assert sched.pool.free_slots == 2
    assert sched.pool.blocks.used_blocks == 0
    assert sched.pool.blocks.allocs == sched.pool.blocks.frees > 0
    # never more in flight than slots
    assert sched.stats.queue_depth_max >= 1


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "xlstm-125m"])
def test_scheduler_parity_recurrent_families(arch):
    """Hybrid (mamba+attn+moe) and ssm stacks decode correctly through
    the pool (exact-length prefill, per-slot write indices)."""
    cfg = _f32_cfg(arch)
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 2, 10)
    sched = Scheduler(cfg, params, num_slots=2, max_len=24, block_size=4)
    assert not sched._can_pad
    reqs = [Request(rid=i, prompt=toks[i, :6 + 3 * i], max_new=3)
            for i in range(2)]
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=100)
    _assert_greedy_parity(cfg, params, sched, reqs)


def test_scheduler_eos_frees_slot_early():
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 1, 8)
    # discover what greedy generates, then use token #2 as EOS
    probe = Scheduler(cfg, params, num_slots=1, max_len=32)
    probe.submit(Request(rid=0, prompt=toks[0], max_new=6))
    gen = probe.run(max_steps=50)[0]
    eos = int(gen[2])
    sched = Scheduler(cfg, params, num_slots=1, max_len=32)
    sched.submit(Request(rid=0, prompt=toks[0], max_new=6, eos_id=eos))
    out = sched.run(max_steps=50)[0]
    assert out.tolist() == gen[:3].tolist()     # stopped AT the eos token
    assert sched.pool.free_slots == 1           # slot freed early
    assert sched.stats.decode_steps < probe.stats.decode_steps


def test_scheduler_token_budget_admission():
    """A page pool too small for two concurrent requests serializes
    them instead of failing."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 2, 8)
    sched = Scheduler(cfg, params, num_slots=2, max_len=16, block_size=4,
                      num_blocks=3)     # 12 tokens of budget
    for i in range(2):
        sched.submit(Request(rid=i, prompt=toks[i], max_new=4))
    res = sched.run(max_steps=200)
    assert len(res) == 2 and sched.stats.completed == 2
    assert sched.pool.blocks.high_water <= 3


def test_scheduler_submit_validation():
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    sched = Scheduler(cfg, params, num_slots=1, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        sched.submit(Request(rid=0, prompt=np.zeros(12, np.int32),
                             max_new=8))
    with pytest.raises(ValueError, match="seed"):
        sched.submit(Request(rid=1, prompt=np.zeros(4, np.int32),
                             max_new=4, temperature=0.7))


def test_scheduler_static_policy_needs_more_steps():
    """Same trace, same kernels: static batching must spend at least as
    many decode steps as continuous (strictly more on mixed lengths)."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 6, 8)
    lens = [8, 4, 8, 4, 8, 4]
    news = [4, 12, 4, 12, 4, 12]

    def serve(policy):
        s = Scheduler(cfg, params, num_slots=2, max_len=24, block_size=4,
                      policy=policy)
        for i in range(6):
            s.submit(Request(rid=i, prompt=toks[i, :lens[i]],
                             max_new=news[i]))
        r = s.run(max_steps=500)
        assert len(r) == 6
        return s

    st, ct = serve("static"), serve("continuous")
    assert ct.stats.decode_steps < st.stats.decode_steps
    # identical outputs: policy changes scheduling, not results
    for i in range(6):
        assert st.results[i].tolist() == ct.results[i].tolist()


def test_scheduler_hot_swap_mid_stream():
    """Swapping weights between steps changes subsequent tokens without
    disturbing the in-flight cache bookkeeping."""
    cfg = _f32_cfg("qwen3-0.6b")
    p1, _ = init_lm(cfg, KEY)
    p2, _ = init_lm(cfg, jax.random.PRNGKey(7))
    toks = _prompts(cfg, 1, 8)

    def serve(swap_to=None):
        s = Scheduler(cfg, p1, num_slots=1, max_len=32)
        s.submit(Request(rid=0, prompt=toks[0], max_new=10))
        for _ in range(4):
            s.step()
        if swap_to is not None:
            s.set_params(swap_to)
        out = s.run(max_steps=100)[0]
        return s, out

    _, base = serve()
    s2, swapped = serve(p2)
    assert s2.stats.hot_swaps == 1
    n_before = 5    # 1 prefill token + 4 decode steps
    assert swapped[:n_before].tolist() == base[:n_before].tolist()
    assert swapped.tolist() != base.tolist()


# ---------------------------------------------------------------------------
# engine satellites
# ---------------------------------------------------------------------------


def test_engine_sample_rejects_missing_key():
    from repro.serve.engine import Engine

    cfg = get_config("qwen3-0.6b", smoke=True)
    params, _ = init_lm(cfg, KEY)
    engine = Engine(cfg, params, max_len=32)
    prompts = jnp.ones((1, 8), jnp.int32)
    with pytest.raises(ValueError, match="PRNG key"):
        engine.generate(prompts, steps=4, temperature=0.8)
    # sampling with a key still works
    out = engine.generate(prompts, steps=4, temperature=0.8, key=KEY)
    assert out.shape == (1, 12)


def test_engine_cache_template_allocated_once(monkeypatch):
    from repro.models import lm as lm_mod
    from repro.serve.engine import Engine

    cfg = get_config("qwen3-0.6b", smoke=True)
    params, _ = init_lm(cfg, KEY)
    engine = Engine(cfg, params, max_len=32)
    calls = []
    orig = lm_mod.init_cache
    monkeypatch.setattr(lm_mod, "init_cache",
                        lambda *a, **k: calls.append(a) or orig(*a, **k))
    prompts = jnp.ones((2, 8), jnp.int32)
    engine.generate(prompts, steps=4)
    engine.generate(prompts, steps=4)
    engine.generate(prompts, steps=4)
    assert len(calls) == 1      # template hoisted out of generate()


def test_engine_greedy_matches_full_forward_argmax():
    """Satellite: greedy generate == full-context argmax, token for
    token."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    from repro.serve.engine import Engine

    engine = Engine(cfg, params, max_len=24)
    toks = jnp.asarray(_prompts(cfg, 2, 8))
    out = np.asarray(engine.generate(toks, steps=6))
    for b in range(2):
        for i in range(6):
            lg, _ = lm_forward(
                params, cfg, {"tokens": jnp.asarray(out[None, b, :8 + i])})
            assert int(jnp.argmax(lg[0, -1])) == int(out[b, 8 + i]), (b, i)


# ---------------------------------------------------------------------------
# registry / winner export / integration
# ---------------------------------------------------------------------------


def _tiny_gan_population(tmp_path, rounds=1):
    """Real launch/ltfb.py run (GAN smoke) with checkpoints."""
    from repro.launch import ltfb

    ckpt_dir = str(tmp_path / "pop")
    rc = ltfb.main([
        "--arch", "icf-cyclegan", "--smoke", "--trainers", "2",
        "--rounds", str(rounds), "--steps-per-round", "1",
        "--batch", "8", "--samples", "192", "--samples-per-file", "64",
        "--num-ranks", "1", "--ckpt-dir", ckpt_dir,
        "--data-dir", str(tmp_path / "data")])
    assert rc == 0
    return ckpt_dir


def test_winner_export_and_registry(tmp_path):
    from repro.checkpoint import ckpt
    from repro.configs.icf_cyclegan import SMOKE
    from repro.models.icf_cyclegan import init_cyclegan
    from repro.serve import registry as reg

    ckpt_dir = _tiny_gan_population(tmp_path, rounds=1)
    like, _ = init_cyclegan(SMOKE, KEY)
    path, info = reg.export_winner(ckpt_dir, like)
    assert info["step"] == 1 and info["trainer"] in (0, 1)
    assert reg.latest_winner_step(ckpt_dir) == 1

    r = reg.ModelRegistry(ckpt_dir, like)
    params = r.load()
    assert r.step == 1 and not r.swaps
    assert jax.tree.structure(params) == jax.tree.structure(like)
    assert not r.refresh()                       # nothing newer

    # a newer population step appears -> auto_export picks it up
    pop = ckpt.restore_population(ckpt_dir, 1, {"params": like,
                                                "opt_state": {}})
    ckpt.save_population(ckpt_dir, 2, pop)
    r2 = reg.ModelRegistry(ckpt_dir, like, auto_export=True)
    r2.load()
    assert r2.step == 1 or r2.step == 2          # loaded something
    assert r2.refresh() is False or r2.step == 2
    assert reg.latest_winner_step(ckpt_dir) == 2


def test_serve_cli_lm_end_to_end_with_hot_swap(tmp_path, monkeypatch,
                                               capsys):
    """Acceptance: launch/serve.py loads a winner exported from a real
    launch/ltfb.py population checkpoint and hot-swaps a newer winner
    mid-stream."""
    from repro.checkpoint import ckpt
    from repro.launch import ltfb, serve
    from repro.serve import registry as reg
    from repro.serve import scheduler as sched_mod

    ckpt_dir = str(tmp_path / "pop")
    rc = ltfb.main([
        "--arch", "qwen3-0.6b", "--smoke", "--trainers", "2",
        "--rounds", "1", "--steps-per-round", "1", "--batch", "4",
        "--seq", "16", "--samples", "96", "--samples-per-file", "32",
        "--num-ranks", "1", "--ckpt-dir", ckpt_dir,
        "--data-dir", str(tmp_path / "data")])
    assert rc == 0
    assert ckpt.latest_population_step(ckpt_dir) == 1

    # drop a newer population step after scheduler step 3: the serving
    # loop (watch-every) must export + hot-swap it mid-stream
    orig_step = sched_mod.Scheduler.step
    fired = []

    def step_with_new_ckpt(self):
        if self._step_count == 3 and not fired:
            fired.append(True)
            cfg = get_config("qwen3-0.6b", smoke=True)
            like, _ = init_lm(cfg, KEY)
            pop = ckpt.restore_population(
                ckpt_dir, 1, {"params": like, "opt_state": {}})
            ckpt.save_population(ckpt_dir, 2, pop)
        orig_step(self)

    monkeypatch.setattr(sched_mod.Scheduler, "step", step_with_new_ckpt)
    rc = serve.main([
        "--arch", "qwen3-0.6b", "--smoke", "--ckpt-dir", ckpt_dir,
        "--watch-every", "2", "--requests", "6", "--slots", "2",
        "--max-new", "8", "--prompt-lens", "8,12"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "winner: step=1" in out
    assert "serving_step=2" in out           # hot-swapped mid-stream
    assert "hot_swaps=1" in out
    assert "completed=6" in out
    assert reg.latest_winner_step(ckpt_dir) == 2


def test_serve_cli_surrogate_end_to_end(tmp_path, capsys):
    """GAN winner from a real population checkpoint answers batched
    surrogate queries through the CLI."""
    from repro.launch import serve

    ckpt_dir = _tiny_gan_population(tmp_path, rounds=1)
    rc = serve.main([
        "--arch", "icf-cyclegan", "--smoke", "--ckpt-dir", ckpt_dir,
        "--queries", "5", "--query-batch", "4"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "workload=surrogate" in out
    assert "completed=5" in out


def test_surrogate_engine_matches_direct_forward():
    from repro.configs.icf_cyclegan import SMOKE
    from repro.models import icf_cyclegan as cg
    from repro.serve.surrogate import SurrogateEngine

    params, _ = cg.init_cyclegan(SMOKE, KEY)
    eng = SurrogateEngine(SMOKE, params, max_batch=16, bucket=4)
    rng = np.random.default_rng(0)
    xs = {i: rng.normal(size=(3 + i, SMOKE.input_dim)).astype(np.float32)
          for i in range(4)}
    for i, x in xs.items():
        eng.submit(i, x)
    res = eng.run(max_steps=20)
    assert eng.stats.completed == 4
    for i, x in xs.items():
        ref = np.asarray(cg.predict(params["gen"], jnp.asarray(x))
                         .astype(jnp.float32))
        np.testing.assert_allclose(res[i], ref, atol=1e-5)
