"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention, rmsnorm
from repro.kernels.ref import flash_attention_ref, rmsnorm_ref

KEY = jax.random.PRNGKey(7)


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 4, 2, 256, 64),     # GQA 2:1
    (1, 8, 2, 128, 128),    # GQA 4:1, MXU-width head
    (2, 4, 1, 512, 32),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, H, Hkv, S, D, dtype, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("block_q,block_k", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shapes(block_q, block_k):
    B, H, S, D = 1, 2, 256, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True,
                          block_q=block_q, block_k=block_k)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("rows,d", [(8, 128), (64, 256), (33, 512),
                                    (256, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(rows, d, dtype):
    x = jax.random.normal(KEY, (rows, d), dtype)
    s = jax.random.normal(jax.random.fold_in(KEY, 1), (d,), dtype)
    out = rmsnorm(x, s)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


def test_rmsnorm_3d_shape():
    x = jax.random.normal(KEY, (4, 7, 128), jnp.float32)
    s = jnp.ones((128,), jnp.float32)
    out = rmsnorm(x, s)
    assert out.shape == x.shape
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(rmsnorm_ref(x, s)), atol=1e-5)


def test_flash_jax_twin_matches_kernel():
    """kernels/flash_attention (Pallas) == models.layers.flash_attention_jax
    (the lowering twin used inside compiled models)."""
    from repro.models.layers import flash_attention_jax
    B, H, Hkv, S, D = 2, 4, 2, 256, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    twin = flash_attention_jax(q, k, v, True, 64)
    kern = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), causal=True,
                           block_q=64, block_k=64).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(twin), np.asarray(kern), atol=2e-5)


@pytest.mark.parametrize("B,S,d,N,bd,ck", [
    (2, 64, 32, 8, 16, 32),
    (1, 96, 48, 16, 48, 24),
    (2, 128, 64, 16, 32, 64),
])
def test_mamba_scan_kernel_matches_ref(B, S, d, N, bd, ck):
    from repro.kernels.mamba_scan import mamba_scan
    from repro.kernels.ref import mamba_scan_ref
    ks = jax.random.split(KEY, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, d))) * 0.1
    xc = jax.random.normal(ks[1], (B, S, d))
    bm = jax.random.normal(ks[2], (B, S, N)) * 0.5
    cm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    a = -jnp.exp(jax.random.normal(ks[4], (d, N)) * 0.3)
    out = mamba_scan(dt, xc, bm, cm, a, block_d=bd, chunk=ck,
                     interpret=True)
    ref = mamba_scan_ref(dt, xc, bm, cm, a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("B,S,d,H,bb,ck", [
    (2, 40, 64, 4, 2, 8),
    (4, 64, 128, 4, 4, 64),
    (3, 33, 96, 2, 1, 11),
])
def test_slstm_kernel_matches_ref(B, S, d, H, bb, ck):
    from repro.kernels.ref import slstm_ref
    from repro.kernels.slstm import slstm_scan
    gx = jax.random.normal(KEY, (B, S, 4 * d), jnp.float32)
    r = jax.random.normal(jax.random.fold_in(KEY, 1),
                          (H, d // H, 4 * d // H), jnp.float32) / np.sqrt(d)
    out = slstm_scan(gx, r, block_b=bb, chunk=ck, interpret=True)
    ref = slstm_ref(gx, r, H)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_flash_vjp_matches_dense_grads():
    from repro.models.layers import dense_attention, flash_attention_jax
    B, S, H, Hkv, D = 2, 96, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)

    gf = jax.grad(lambda *a: jnp.sum(jnp.sin(
        flash_attention_jax(*a, True, 32))), argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(lambda *a: jnp.sum(jnp.sin(
        dense_attention(*a, True))), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
