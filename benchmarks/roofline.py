"""Assemble the roofline table (EXPERIMENTS.md §Roofline) from the
dry-run JSON reports in experiments/dryrun/."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load_reports(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        stem = os.path.basename(path)[:-5]
        # perf-iteration variants carry a __suffix in the filename
        parts = stem.split("__")
        r["variant"] = " [" + parts[3] + "]" if len(parts) > 3 else ""
        out.append(r)
    return out


def table(reports: List[Dict], mesh: str = "1pod_16x16") -> str:
    """Markdown roofline table for one mesh."""
    hdr = ("| arch | shape | compute | memory | collective | bottleneck "
           "| useful_flops | mfu@roofline | mfu@kernel | resident/chip |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in reports:
        if not r.get("ok") or r.get("mesh") != mesh or "roofline" not in r:
            continue
        rf = r["roofline"]
        mem = r["memory"]
        rk = r.get("roofline_kernel") or {}
        kmfu = f"{rk['mfu']:.2%}" if rk.get("credited_tags") else "-"
        lines.append(
            f"| {r['arch']}{r.get('variant','')} | {r['shape']} "
            f"| {rf['t_compute']*1e3:.1f}ms | {rf['t_memory']*1e3:.1f}ms "
            f"| {rf['t_collective']*1e3:.1f}ms | {rf['bottleneck']} "
            f"| {rf['useful_flops_ratio']:.2f} | {rf['mfu']:.2%} "
            f"| {kmfu} "
            f"| {mem.get('analytic_resident_bytes', 0)/2**30:.2f}G |")
    return hdr + "\n".join(lines)


def run(report, quick: bool = False):
    reports = load_reports()
    ok = [r for r in reports if r.get("ok")]
    fail = [r for r in reports if not r.get("ok")]
    for r in ok:
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        rk = r.get("roofline_kernel") or {}
        kmfu = f";mfu_kernel={rk['mfu']:.4f}" if rk.get("credited_tags") \
            else ""
        variant = r.get("variant", "").strip(" []")
        vtag = f"/{variant}" if variant else ""
        report.add(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}{vtag}",
            rf["step_time"] * 1e6,
            f"bottleneck={rf['bottleneck']};mfu={rf['mfu']:.4f};"
            f"useful={rf['useful_flops_ratio']:.2f}{kmfu}")
    report.add("roofline/cells_ok", float(len(ok)), f"failed={len(fail)}")
    return ok, fail


if __name__ == "__main__":
    reports = load_reports()
    print(table(reports, "1pod_16x16"))
    print()
    print(table(reports, "2pod_2x16x16"))
