"""Paper Fig. 11 — LTFB strong scaling (the headline result: 70.2x at 64
trainers, 109% parallel efficiency).

K trainers each own a disjoint 1/K partition of the on-disk bundle
manifest, served by their own distributed datastore (preload mode,
block partitioning = the paper's data silos).  Steady-state epoch time
per trainer = (samples/K/128) steps.  Trainer compute is MEASURED
(jit'd GAN step); trainers run concurrently on real hardware, so the
parallel epoch time is the per-trainer time (they time-share this
1-core container — both the serialized wall time and the derived
parallel time are reported).  Tournament overhead is measured and
included.  Superlinearity in the paper comes from data-store cache
effects (aggregate memory grows with K) — reproduced here via the
store's cache-hit accounting, reported per K.
"""
from __future__ import annotations

import tempfile
import time

import jax.numpy as jnp

from benchmarks.common import (BENCH_CCFG, PAPER_BATCH, PAPER_OPT,
                               CsvReport, make_jag_arrays, make_jag_bundles,
                               timeit)
from repro.core.population import TrainerFns
from repro.core.tournament import (DataPlan, TournamentConfig,
                                   TournamentOrchestrator)
from repro.train.steps import make_gan_steps


def run(report: CsvReport, quick: bool = False):
    n = 8_192 if quick else 32_768
    x, y = make_jag_arrays(n + 1024)
    val = {"x": jnp.asarray(x[n:]), "y": jnp.asarray(y[n:])}
    root = tempfile.mkdtemp(prefix="fig11_bundles_")
    files = make_jag_bundles(root, n, samples_per_file=n // 16)
    fns = TrainerFns(*make_gan_steps(BENCH_CCFG, PAPER_OPT))

    # measured per-step time (identical across trainers)
    params, opt_state, hp = fns.init(0)
    batch = {"x": jnp.asarray(x[:PAPER_BATCH]),
             "y": jnp.asarray(y[:PAPER_BATCH])}
    st = [params, opt_state]

    def one():
        st[0], st[1], _ = fns.train_step(st[0], st[1], batch, hp)
        return st[0]

    t_step = timeit(one, warmup=2, iters=4 if quick else 10)

    rows = []
    base = None
    TOURN_INTERVAL = 100   # paper: tournaments at mini-batch intervals
    for K in (1, 2, 4, 8):
        cfg = TournamentConfig(
            trainers=K, scope="generator", batch_size=PAPER_BATCH,
            partition="block",           # paper's input-space data silos
            num_ranks=2, tournament_batches=1,
            tournament_batch_size=256, seed=0)
        orch = TournamentOrchestrator(fns, DataPlan.jag_cyclegan(files),
                                      cfg)
        try:
            orch.tournament()                # warm up (jit compile)
            t0 = time.perf_counter()
            orch.tournament()
            t_tourn = time.perf_counter() - t0

            steps_per_epoch = n // K // PAPER_BATCH
            tourns_per_epoch = max(0, steps_per_epoch // TOURN_INTERVAL)
            epoch_parallel = steps_per_epoch * t_step \
                + tourns_per_epoch * t_tourn
            base = base or epoch_parallel
            speedup = base / epoch_parallel
            eff = speedup / K
            # quality check: short run, no loss of validation quality
            orch.run(rounds=2, steps_per_round=10 if quick else 25)
            vloss = orch.population.best_metric(val)
            stats = orch.stats()["total"]
            hits = stats["cache_hits"]
            hit_rate = hits / max(1, hits + stats["cache_misses"])
            rows.append((K, epoch_parallel, speedup, eff, vloss))
            report.add(
                f"fig11/ltfb_trainers={K}", t_step * 1e6,
                f"epoch_s={epoch_parallel:.3f};speedup={speedup:.2f};"
                f"efficiency={eff:.2f};tournament_s={t_tourn:.3f};"
                f"val={vloss:.4f};cache_hit_rate={hit_rate:.3f};"
                f"data_exchange_MB={stats['exchange_bytes'] / 1e6:.1f}")
        finally:
            orch.close()
    return rows


if __name__ == "__main__":
    r = CsvReport()
    run(r)
    r.dump()
