"""Paper Fig. 11 — LTFB strong scaling (the headline result: 70.2x at 64
trainers, 109% parallel efficiency).

K trainers each own a disjoint 1/K partition of the on-disk bundle
manifest, served by their own distributed datastore (preload mode,
block partitioning = the paper's data silos).  Steady-state epoch time
per trainer = (samples/K/128) steps.  Trainer compute is MEASURED
(jit'd GAN step); trainers run concurrently on real hardware, so the
parallel epoch time is the per-trainer time (they time-share this
1-core container — both the serialized wall time and the derived
parallel time are reported).  Tournament overhead comes from the
orchestrator's own accounting (``stats()["tournament_seconds"]``), and
the live per-round speedup/efficiency the telemetry layer computes
online is reported next to the epoch-model numbers.  Superlinearity in
the paper comes from data-store cache effects (aggregate memory grows
with K) — reproduced here via the store's cache-hit accounting,
reported per K.

A twin arm (same config, telemetry fully on vs fully off) bounds the
instrumentation cost: training samples/s with tracing + genealogy +
Prometheus snapshots attached must stay within 5% of bare.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax.numpy as jnp

from benchmarks.common import (BENCH_CCFG, PAPER_BATCH, PAPER_OPT,
                               CsvReport, make_jag_arrays, make_jag_bundles,
                               timeit)
from repro.core.population import TrainerFns
from repro.core.tournament import (DataPlan, TournamentConfig,
                                   TournamentOrchestrator)
from repro.train.steps import make_gan_steps
from repro.train.telemetry import (GenealogyLog, TrainTelemetry,
                                   train_prometheus, write_prom)

TOURN_INTERVAL = 100   # paper: tournaments at mini-batch intervals


def _cfg(K: int, quick: bool) -> TournamentConfig:
    return TournamentConfig(
        trainers=K, scope="generator", batch_size=PAPER_BATCH,
        partition="block",           # paper's input-space data silos
        num_ranks=2, tournament_batches=1,
        tournament_batch_size=256, seed=0)


def ltfb_samples_per_s(fns, files, quick: bool, instrumented: bool,
                       K: int = 2) -> float:
    """Steady-state training samples/s of a K-trainer run, measured
    from the orchestrator's own round-wall accounting (warm-up round
    excluded so nobody pays the jit compile in the measured window).

    ``instrumented=True`` attaches the full telemetry stack: trace
    spans on every step/exchange/eval, genealogy records per match,
    and a Prometheus snapshot written each round — the twin of what
    ``launch/ltfb.py --trace-out --prom-out`` wires up.
    """
    spr = 10 if quick else 25
    rounds = 2 if quick else 3
    tel = TrainTelemetry() if instrumented else None
    tmp = tempfile.mkdtemp(prefix="fig11_twin_") if instrumented else None
    gen = GenealogyLog(os.path.join(tmp, "genealogy.jsonl")) \
        if instrumented else None
    orch = TournamentOrchestrator(fns, DataPlan.jag_cyclegan(files),
                                  _cfg(K, quick), telemetry=tel,
                                  genealogy=gen)
    if instrumented:
        prom_path = os.path.join(tmp, "PROM.prom")

        def on_round(o):
            write_prom(train_prometheus(o.stats(), tel.phase_seconds),
                       prom_path)
        orch.on_round = on_round
    try:
        orch.run(rounds=1, steps_per_round=spr)      # warm (jit compile)
        s0 = orch.stats()
        orch.run(rounds=rounds, steps_per_round=spr)
        s1 = orch.stats()
        samples = (s1["steps"] - s0["steps"]) * PAPER_BATCH
        dt = s1["round_wall_seconds"] - s0["round_wall_seconds"]
        return samples / max(dt, 1e-9)
    finally:
        orch.close()
        if gen is not None:
            gen.close()


def _bestcase_overhead(runs: dict) -> float:
    """Best-vs-best samples/s ratio (noise only ever slows an arm, so
    each arm's best repeat is its least-contaminated estimate)."""
    base = max(runs["bare"])
    arm = max(runs["instrumented"])
    return max(0.0, (base - arm) / max(base, 1e-9))


def run(report: CsvReport, quick: bool = False, json_path: str = None):
    n = 8_192 if quick else 32_768
    x, y = make_jag_arrays(n + 1024)
    val = {"x": jnp.asarray(x[n:]), "y": jnp.asarray(y[n:])}
    root = tempfile.mkdtemp(prefix="fig11_bundles_")
    files = make_jag_bundles(root, n, samples_per_file=n // 16)
    fns = TrainerFns(*make_gan_steps(BENCH_CCFG, PAPER_OPT))

    # measured per-step time (identical across trainers)
    params, opt_state, hp = fns.init(0)
    batch = {"x": jnp.asarray(x[:PAPER_BATCH]),
             "y": jnp.asarray(y[:PAPER_BATCH])}
    st = [params, opt_state]

    def one():
        st[0], st[1], _ = fns.train_step(st[0], st[1], batch, hp)
        return st[0]

    t_step = timeit(one, warmup=2, iters=4 if quick else 10)

    rows = []
    base = None
    summary = {"t_step_s": t_step, "arms": {}}
    for K in (1, 2, 4, 8):
        tel = TrainTelemetry()
        orch = TournamentOrchestrator(fns, DataPlan.jag_cyclegan(files),
                                      _cfg(K, quick), telemetry=tel)
        per_round = []
        orch.on_round = lambda o: per_round.append(o.last_efficiency)
        try:
            orch.tournament()                # warm up (jit compile)
            # quality check: short run, no loss of validation quality —
            # and the source of all measured timings below
            orch.run(rounds=2, steps_per_round=10 if quick else 25)
            stt = orch.stats()
            t_tourn = stt["tournament_seconds"] / max(stt["round"], 1)
            steps_per_epoch = n // K // PAPER_BATCH
            tourns_per_epoch = max(0, steps_per_epoch // TOURN_INTERVAL)
            epoch_parallel = steps_per_epoch * t_step \
                + tourns_per_epoch * t_tourn
            base = base or epoch_parallel
            speedup = base / epoch_parallel
            eff = speedup / K
            vloss = orch.population.best_metric(val)
            stats = stt["total"]
            hits = stats["cache_hits"]
            hit_rate = hits / max(1, hits + stats["cache_misses"])
            # the telemetry layer's ONLINE per-round efficiency (last
            # round: fully warm; round 1 pays residual compile)
            live = per_round[-1] or {}
            for r_i, e in enumerate(per_round):
                if e:
                    print(f"# fig11 K={K} round {r_i}: live "
                          f"speedup={e['speedup']:.2f} "
                          f"efficiency={e['efficiency']:.2f} "
                          f"parallel_samples_per_s="
                          f"{e['parallel_samples_per_s']:.0f}")
            rows.append((K, epoch_parallel, speedup, eff, vloss))
            summary["arms"][f"K={K}"] = {
                "epoch_s": epoch_parallel, "speedup": speedup,
                "efficiency": eff, "val": vloss,
                "tournament_s": t_tourn, "cache_hit_rate": hit_rate,
                "data_wait_s": stt["data_wait_seconds"],
                "live_rounds": [e for e in per_round if e]}
            report.add(
                f"fig11/ltfb_trainers={K}", t_step * 1e6,
                f"epoch_s={epoch_parallel:.3f};speedup={speedup:.2f};"
                f"efficiency={eff:.2f};tournament_s={t_tourn:.3f};"
                f"val={vloss:.4f};cache_hit_rate={hit_rate:.3f};"
                f"data_exchange_MB={stats['exchange_bytes'] / 1e6:.1f};"
                f"data_wait_s={stt['data_wait_seconds']:.3f};"
                f"live_speedup={live.get('speedup', 0.0):.2f};"
                f"live_efficiency={live.get('efficiency', 0.0):.2f}")
        finally:
            orch.close()

    # telemetry twin: full instrumentation must cost <= 5% samples/s
    runs = {"bare": [], "instrumented": []}
    for _ in range(2):
        runs["bare"].append(
            ltfb_samples_per_s(fns, files, quick, instrumented=False))
        runs["instrumented"].append(
            ltfb_samples_per_s(fns, files, quick, instrumented=True))
    overhead = _bestcase_overhead(runs)
    if overhead > 0.05:
        print(f"# fig11 telemetry overhead {overhead * 100:.1f}% over "
              "budget on first rounds; re-measuring back-to-back")
        for _ in range(8):
            runs["bare"].append(
                ltfb_samples_per_s(fns, files, quick, instrumented=False))
            runs["instrumented"].append(
                ltfb_samples_per_s(fns, files, quick, instrumented=True))
        overhead = _bestcase_overhead(runs)
    print(f"# fig11 telemetry overhead (instrumented vs bare twin, "
          f"best of repeats): {overhead * 100:.1f}% "
          f"(bare={max(runs['bare']):.0f} samples/s, "
          f"instrumented={max(runs['instrumented']):.0f})")
    assert overhead <= 0.05, \
        f"training telemetry overhead {overhead * 100:.1f}% exceeds " \
        "the 5% budget"
    report.add("fig11/telemetry_overhead", overhead * 1e6,
               f"bare_samples_per_s={max(runs['bare']):.0f};"
               f"instrumented_samples_per_s="
               f"{max(runs['instrumented']):.0f}")
    summary["telemetry_overhead"] = overhead
    summary["twin"] = {k: sorted(v) for k, v in runs.items()}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"# fig11 wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write BENCH_ltfb.json summary here")
    args = ap.parse_args()
    r = CsvReport()
    run(r, quick=args.quick, json_path=args.json)
    r.dump()
