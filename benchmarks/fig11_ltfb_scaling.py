"""Paper Fig. 11 — LTFB strong scaling (the headline result: 70.2x at 64
trainers, 109% parallel efficiency).

K trainers each own a disjoint 1/K partition; steady-state epoch time
per trainer = (samples/K/128) steps.  Trainer compute is MEASURED
(jit'd GAN step); trainers run concurrently on real hardware, so the
parallel epoch time is the per-trainer time (they time-share this
1-core container — both the serialized wall time and the derived
parallel time are reported).  Tournament overhead is measured and
included.  Superlinearity in the paper comes from data-store cache
effects (aggregate memory grows with K) — reproduced here via the
store's cache-hit accounting.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (BENCH_CCFG, PAPER_BATCH, PAPER_OPT,
                               CsvReport, make_jag_arrays, silo_partition,
                               timeit)
from repro.core.population import Population, TrainerFns
from repro.train.steps import make_gan_steps


def run(report: CsvReport, quick: bool = False):
    n = 8_192 if quick else 32_768
    x, y = make_jag_arrays(n + 1024)
    val = {"x": jnp.asarray(x[n:]), "y": jnp.asarray(y[n:])}
    init, train_step, metric = make_gan_steps(BENCH_CCFG, PAPER_OPT)
    fns = TrainerFns(init, train_step, metric)

    # measured per-step time (identical across trainers)
    params, opt_state, hp = init(0)
    batch = {"x": jnp.asarray(x[:PAPER_BATCH]),
             "y": jnp.asarray(y[:PAPER_BATCH])}
    st = [params, opt_state]

    def one():
        st[0], st[1], _ = train_step(st[0], st[1], batch, hp)
        return st[0]

    t_step = timeit(one, warmup=2, iters=4 if quick else 10)

    rows = []
    base = None
    TOURN_INTERVAL = 100   # paper: tournaments at mini-batch intervals
    for K in (1, 2, 4, 8):
        silos = silo_partition(x[:n], K)
        def loader_for(k):
            rng = np.random.default_rng(k)
            pool = silos[k]
            def loader():
                idx = rng.choice(pool, PAPER_BATCH)
                return {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}
            return loader

        loaders = [loader_for(k) for k in range(K)]
        tb = [[{"x": jnp.asarray(x[silos[k][:256]]),
                "y": jnp.asarray(y[silos[k][:256]])}]
              for k in range(K)]
        pop = Population(fns, loaders, tb, scope="generator", seed=0)
        pop.tournament()                    # warm up (jit compile)
        t0 = time.perf_counter()
        pop.tournament()
        t_tourn = time.perf_counter() - t0

        steps_per_epoch = n // K // PAPER_BATCH
        tourns_per_epoch = max(0, steps_per_epoch // TOURN_INTERVAL)
        epoch_parallel = steps_per_epoch * t_step \
            + tourns_per_epoch * t_tourn
        base = base or epoch_parallel
        speedup = base / epoch_parallel
        eff = speedup / K
        # quality check: short run, no loss of validation quality
        pop.run(rounds=2, steps_per_round=10 if quick else 25)
        vloss = pop.best_metric(val)
        rows.append((K, epoch_parallel, speedup, eff, vloss))
        report.add(
            f"fig11/ltfb_trainers={K}", t_step * 1e6,
            f"epoch_s={epoch_parallel:.3f};speedup={speedup:.2f};"
            f"efficiency={eff:.2f};tournament_s={t_tourn:.3f};"
            f"val={vloss:.4f}")
    return rows


if __name__ == "__main__":
    r = CsvReport()
    run(r)
    r.dump()
