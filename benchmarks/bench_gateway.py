"""Gateway serving benchmark: concurrent streaming HTTP clients
against the live asyncio front door.

Starts an in-process :class:`repro.serve.gateway.Gateway` over a
smoke-config scheduler and drives it two ways:

* **throughput**: N concurrent streaming clients, measuring wall-clock
  tokens/s, per-request TTFT (time to the FIRST streamed token record,
  i.e. queueing + prefill + the first decode round through the HTTP
  stack), and mean TPOT;
* **overload burst**: a second wave sized past ``--max-queue``,
  counting clean 429 sheds vs completions (admission control under
  pressure, not a crash).

With ``--json PATH`` the summary is written as ``BENCH_gateway.json``
so CI tracks the serving front door's perf trajectory across PRs.

  python -m benchmarks.bench_gateway --quick --json BENCH_gateway.json
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import time

import jax
import numpy as np


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


async def _timed_stream(port: int, prompt, max_new: int):
    """Streaming request with true chunk-arrival TTFT measurement."""
    t0 = time.perf_counter()
    r, w = await asyncio.open_connection("127.0.0.1", port)
    payload = json.dumps({"prompt": prompt, "max_new": max_new}).encode()
    w.write((f"POST /v1/generate HTTP/1.1\r\nHost: b\r\n"
             f"Content-Length: {len(payload)}\r\n\r\n").encode() + payload)
    await w.drain()
    head = await r.readuntil(b"\r\n\r\n")
    status = int(head.split()[1])
    ttft = None
    n_tokens = 0
    if status == 200:
        while True:
            size_line = await r.readline()
            n = int(size_line.strip() or b"0", 16)
            if n == 0:
                break
            chunk = await r.readexactly(n + 2)
            rec = json.loads(chunk[:n])
            if "token" in rec:
                n_tokens += 1
                if ttft is None:
                    ttft = time.perf_counter() - t0
    else:
        await r.read()
    total = time.perf_counter() - t0
    w.close()
    return (ttft if ttft is not None else total), total, n_tokens, status


async def _drive(gw, port, n_requests, prompt_lens, max_new, burst):
    rng = np.random.default_rng(0)
    vocab = gw.sched.cfg.vocab_size

    def prompt(i):
        return rng.integers(0, vocab,
                            prompt_lens[i % len(prompt_lens)]).tolist()

    # throughput wave: all clients in flight together
    t0 = time.perf_counter()
    waves = await asyncio.gather(*[
        _timed_stream(port, prompt(i), max_new) for i in range(n_requests)])
    wall = time.perf_counter() - t0
    ok = [wv for wv in waves if wv[3] == 200]
    tokens = sum(wv[2] for wv in ok)
    ttfts = [wv[0] for wv in ok]
    tpots = [(wv[1] - wv[0]) / max(wv[2] - 1, 1) for wv in ok]

    # overload burst: size it past the queue bound, count clean sheds
    burst_res = await asyncio.gather(*[
        _timed_stream(port, prompt(i), max_new) for i in range(burst)])
    statuses = [b[3] for b in burst_res]
    return {
        "requests": n_requests,
        "completed": len(ok),
        "wall_s": round(wall, 3),
        "tok_s": round(tokens / wall, 2) if wall else 0.0,
        "ttft_mean_ms": round(1e3 * float(np.mean(ttfts)), 1) if ttfts else 0,
        "ttft_p95_ms": round(1e3 * _percentile(ttfts, 95), 1),
        "tpot_mean_ms": round(1e3 * float(np.mean(tpots)), 1) if tpots else 0,
        "burst": burst,
        "burst_ok": sum(1 for s in statuses if s == 200),
        "burst_429": sum(1 for s in statuses if s == 429),
        "burst_other": sum(1 for s in statuses if s not in (200, 429)),
    }


def run(quick: bool = False, json_path: str = None) -> dict:
    """Build the scheduler + gateway in-process and run both waves."""
    from repro.configs.registry import get_config
    from repro.models.lm import init_lm
    from repro.serve.gateway import Gateway
    from repro.serve.scheduler import Scheduler

    cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                              dtype="float32")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    n_requests = 6 if quick else 16
    max_new = 8 if quick else 24
    # throughput wave must fit the bound; the burst must exceed it
    max_queue = n_requests
    sched = Scheduler(cfg, params, num_slots=2,
                      max_len=64, max_queue=max_queue)
    gw = Gateway(sched, port=0)

    async def main():
        await gw.start()
        try:
            return await _drive(gw, gw.port, n_requests,
                                prompt_lens=(8, 16), max_new=max_new,
                                burst=max_queue + 2 + 4)
        finally:
            await gw.stop()

    summary = asyncio.new_event_loop().run_until_complete(main())
    summary["quick"] = quick
    summary["sched"] = {"slots": 2, "max_queue": max_queue,
                        "max_new": max_new}
    assert summary["completed"] == n_requests, summary
    assert summary["burst_429"] > 0, (
        f"overload burst produced no 429s: {summary}")
    assert summary["burst_other"] == 0, summary
    print(json.dumps(summary, indent=2))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"# bench_gateway wrote {json_path}")
    return summary


def main(argv=None) -> int:
    """CLI: ``python -m benchmarks.bench_gateway [--quick] [--json P]``."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write BENCH_gateway.json summary here")
    args = ap.parse_args(argv)
    run(quick=args.quick, json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
