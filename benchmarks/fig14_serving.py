"""Fig. 14 (beyond-paper) — continuous vs. static batching at serve time.

The ROADMAP north star is a production system answering surrogate /
LM queries at scale; this benchmark measures the scheduling policy that
gets there.  One mixed-length request trace is served twice through the
SAME compiled prefill/decode kernels and the SAME preallocated KV-cache
pool (:mod:`repro.serve.scheduler`):

  * ``static``      — classic batch inference: fill the pool, pad to the
    batch's worst case, run until EVERY request in the batch finishes,
    only then admit the next batch.
  * ``continuous``  — token-budget admission interleaved with decode:
    a finished request's slot is re-filled on the next step.

Reported per policy: wall-clock tokens/s, time-to-first-token
(mean/p95), decode steps, and useful-tokens-per-slot-step (the decode
utilization static batching wastes on its stragglers).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import CsvReport
from repro.configs.registry import get_config
from repro.data.tokens import token_stream
from repro.models.lm import init_lm
from repro.serve.scheduler import Request, Scheduler

# mixed-length trace: short chats + long documents, interleaved so a
# static batch always contains at least one straggler
PROMPT_LENS = (8, 24, 8, 48, 16, 8)
MAX_NEW = (12, 48, 12, 24, 48, 12)


def build_trace(cfg, n_requests: int, seed: int = 0):
    stream = token_stream(n_requests * max(PROMPT_LENS), cfg.vocab_size,
                          seed=seed)
    reqs, off = [], 0
    for i in range(n_requests):
        p = PROMPT_LENS[i % len(PROMPT_LENS)]
        reqs.append(Request(rid=i,
                            prompt=np.asarray(stream[off:off + p], np.int32),
                            max_new=MAX_NEW[i % len(MAX_NEW)]))
        off += p
    return reqs


def serve_once(cfg, params, reqs, policy: str, slots: int, max_len: int):
    sched = Scheduler(cfg, params, num_slots=slots, max_len=max_len,
                      policy=policy)
    for r in reqs:
        sched.submit(Request(rid=r.rid, prompt=r.prompt,
                             max_new=r.max_new))
    sched.run()
    return sched


def run(report: CsvReport, quick: bool = False):
    cfg = get_config("qwen3-0.6b", smoke=True)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    n = 12 if quick else 24
    slots = 4
    max_len = max(p + m for p, m in zip(PROMPT_LENS, MAX_NEW))
    reqs = build_trace(cfg, n)

    # warm the jit caches so the comparison is pure scheduling policy
    serve_once(cfg, params, build_trace(cfg, min(n, len(PROMPT_LENS))),
               "continuous", slots, max_len)

    out = {}
    for policy in ("static", "continuous"):
        sched = serve_once(cfg, params, reqs, policy, slots, max_len)
        d = sched.stats.as_dict()
        out[policy] = d
        util = d["decode_tokens"] / max(d["decode_slot_steps"], 1)
        print(f"# fig14 {policy}: {d['tokens_per_s']:.1f} tok/s "
              f"ttft_mean={d['ttft_mean_s'] * 1e3:.0f}ms "
              f"ttft_p95={d['ttft_p95_s'] * 1e3:.0f}ms "
              f"decode_steps={d['decode_steps']} util={util:.2f}")
        report.add(f"fig14_{policy}_tok_per_s",
                   1e6 / max(d["tokens_per_s"], 1e-9),
                   f"tok/s={d['tokens_per_s']:.1f}")
        report.add(f"fig14_{policy}_ttft_mean",
                   d["ttft_mean_s"] * 1e6,
                   f"p95={d['ttft_p95_s'] * 1e6:.0f}us")

    speedup = out["continuous"]["tokens_per_s"] / \
        max(out["static"]["tokens_per_s"], 1e-9)
    print(f"# fig14 continuous/static tokens/s speedup: {speedup:.2f}x")
    report.add("fig14_continuous_speedup", speedup * 100,
               f"{speedup:.2f}x")
    return out


if __name__ == "__main__":
    r = CsvReport()
    run(r, quick=True)
    r.dump()
