"""Fig. 14 (beyond-paper) — serving schedule + KV-layout benchmark.

The ROADMAP north star is a production system answering surrogate /
LM queries at scale; this benchmark measures the decode hot path that
gets there.  One mixed-length request trace — short chats and long
documents behind a common system-prompt prefix, plus one request whose
total length exceeds the dense layout's per-slot ceiling — is served
through three configurations at EQUAL KV-cache memory:

  * ``static``   — dense slot rows, classic batch inference: fill the
    pool, run until every request in the batch finishes.
  * ``dense``    — the PR-2 continuous-batching baseline: token-budget
    admission + per-request completion over dense ``num_slots x
    max_len`` rows.  Admission is gated by the per-slot ``max_len``
    ceiling: the long request is REJECTED and a short request wastes a
    full row.
  * ``paged``    — the paged KV pool (scattered pages + gather-decode
    kernel) with chunked prefill and copy-on-admit prefix sharing.
    The same memory holds 2x the decode slots because pages are shared;
    the long request is admitted; the shared system prompt prefills
    once and is then mapped, not recomputed.
  * ``paged_notel`` — the paged configuration with ``telemetry=False``:
    the control arm that bounds the cost of per-request tracing (token
    identity asserted; overhead must stay <= 5% tokens/s).
  * ``paged_journal`` — the paged configuration with a write-ahead
    request journal attached (flush per scheduler step + interval-
    bounded fsync): the arm that bounds the durability tax of crash
    recovery (token identity asserted; overhead vs ``paged`` must stay
    <= 5% tokens/s).
  * ``spec``     — the paged configuration plus population speculative
    decoding through the same DecodeSession API: a drafter proposes
    SPEC_TOKENS tokens per round and the target verifies them in one
    multi-token step (ONE fused draft dispatch + one verify per
    round).  The drafter here is the target itself — the accept-rate
    UPPER BOUND (a real deployment drafts with an earlier LTFB
    population checkpoint); the arm proves the mechanics and asserts
    token-identical output vs ``paged``.
  * ``mesh``     — the paged configuration served by the
    :class:`repro.serve.mesh.MeshScheduler` over a ("data", "model")
    device mesh (weights tensor-parallel over `model`, decode batch +
    per-shard page pools over `data`, host-0 admission broadcast);
    runs when >= MESH_DEVICES devices are visible (CI emulates 8) and
    asserts token-identical output vs ``paged``.  On emulated CPU
    devices the wall-clock is a mechanics check, not a speedup claim —
    the arm exists so BENCH_serving.json tracks the mesh path the
    moment real accelerators appear.

Reported per config: wall-clock tokens/s, time-to-first-token
(mean/p95), decode steps, page high-water, prefix-cache hits, and for
``spec`` the draft accept-rate.  With ``--json PATH`` the summary is
written as ``BENCH_serving.json`` so CI tracks the perf trajectory
across PRs; the script exits nonzero on any correctness assertion, and
CI fails the step rather than uploading a stale artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax
import numpy as np

from benchmarks.common import CsvReport
from repro.configs.registry import get_config
from repro.data.tokens import token_stream
from repro.models.lm import init_lm
from repro.serve import telemetry as telemetry_mod
from repro.serve.scheduler import Request, Scheduler

# mixed-length trace: short chats + long documents, interleaved so a
# static batch always contains at least one straggler; every prompt
# starts with the same SYS_LEN-token system prefix (the prefix-sharing
# capacity win the paged layout banks)
SYS_LEN = 32
TAIL_LENS = (4, 16, 4, 40, 8, 4)
MAX_NEW = (12, 24, 12, 24, 24, 12)
# the dense per-slot ceiling: largest regular request, prompt + max_new
DENSE_MAX_LEN = max(SYS_LEN + t + m for t, m in zip(TAIL_LENS, MAX_NEW))
DENSE_SLOTS = 4
BLOCK_SIZE = 16
# equal memory: the paged pool gets exactly the dense pool's tokens
POOL_TOKENS = DENSE_SLOTS * DENSE_MAX_LEN
NUM_BLOCKS = POOL_TOKENS // BLOCK_SIZE
PAGED_SLOTS = 8
# the beyond-ceiling request: admissible only under the paged layout
LONG_PROMPT, LONG_NEW = 96, 24
# draft tokens per speculative round (the spec arm)
SPEC_TOKENS = 3
# the mesh arm: data=2 keeps each shard's pool (NUM_BLOCKS/2 pages) big
# enough for the beyond-ceiling request, model=2 exercises the
# weights-stationary TP axis
MESH_SHAPE = (2, 2)
MESH_DEVICES = MESH_SHAPE[0] * MESH_SHAPE[1]


def build_trace(cfg, n_requests: int, seed: int = 0, with_long: bool = True):
    stream = token_stream(
        SYS_LEN + n_requests * max(TAIL_LENS) + LONG_PROMPT,
        cfg.vocab_size, seed=seed)
    sys_prefix = np.asarray(stream[:SYS_LEN], np.int32)
    reqs, off = [], SYS_LEN
    for i in range(n_requests):
        t = TAIL_LENS[i % len(TAIL_LENS)]
        prompt = np.concatenate(
            [sys_prefix, np.asarray(stream[off:off + t], np.int32)])
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new=MAX_NEW[i % len(MAX_NEW)]))
        off += t
    if with_long:
        # the long document arrives FIRST — chunked prefill must keep
        # admitting/decoding the chat storm behind it instead of
        # stalling the pool for six prefill blocks
        reqs.insert(0, Request(
            rid="long",
            prompt=np.asarray(stream[-LONG_PROMPT:], np.int32),
            max_new=LONG_NEW))
    return reqs


def make_scheduler(cfg, params, mode: str) -> Scheduler:
    if mode in ("static", "dense"):
        return Scheduler(
            cfg, params, num_slots=DENSE_SLOTS, max_len=DENSE_MAX_LEN,
            block_size=BLOCK_SIZE, num_blocks=NUM_BLOCKS, layout="dense",
            policy="static" if mode == "static" else "continuous")
    paged_kw = dict(
        num_slots=PAGED_SLOTS, max_len=DENSE_MAX_LEN,
        block_size=BLOCK_SIZE, num_blocks=NUM_BLOCKS, layout="paged",
        max_seq=LONG_PROMPT + LONG_NEW, prefill_chunk=2 * BLOCK_SIZE,
        max_prefills_per_step=3, policy="continuous",
        # self-draft: the accept-rate upper bound (a deployment drafts
        # with an earlier/smaller LTFB population checkpoint instead)
        draft_params=params if mode == "spec" else None,
        spec_tokens=SPEC_TOKENS if mode == "spec" else 0,
        # the telemetry-off twin of the paged arm bounds tracing cost
        telemetry=mode != "paged_notel")
    if mode == "paged_journal":
        # the durability twin: a real fsync'd journal on a fresh temp
        # file per run, so repeats never replay each other's appends
        from repro.serve.journal import RequestJournal
        fd, path = tempfile.mkstemp(suffix=".fig14.journal.jsonl")
        os.close(fd)
        paged_kw["journal"] = RequestJournal(path)
    if mode == "mesh":
        from repro.serve.mesh import MeshScheduler
        return MeshScheduler(cfg, params, mesh_shape=MESH_SHAPE,
                             **paged_kw)
    return Scheduler(cfg, params, **paged_kw)


def bestcase_overhead(runs, base_mode: str, arm_mode: str) -> float:
    """Overhead of ``arm`` vs ``base`` from each mode's BEST repeat.

    Scheduler overhead is what these twin-arm comparisons measure, and
    machine noise (CI neighbors, GC, writeback) only ever *adds* wall
    time — so each arm's best tokens/s over the round-robin repeats is
    its least-contaminated estimate, and the best-vs-best ratio is a
    far lower-variance overhead estimator than a ratio (or median of
    ratios) of noisy repeats."""
    base = max(r["tokens_per_s"] for r in runs[base_mode])
    arm = max(r["tokens_per_s"] for r in runs[arm_mode])
    return max(0.0, (base - arm) / max(base, 1e-9))


def serve_once(cfg, params, reqs, mode: str) -> dict:
    """Serve the trace once; returns only the summary dicts + results
    so the scheduler (and its device page pools — two full pools for
    the spec arm) can be collected between repeats."""
    sched = make_scheduler(cfg, params, mode)
    for r in reqs:
        try:
            sched.submit(Request(rid=r.rid, prompt=r.prompt,
                                 max_new=r.max_new))
        except ValueError:
            pass                    # counted in the rejected stat
    sched.run()
    if getattr(sched, "journal", None) is not None:
        sched.journal.close()
        os.unlink(sched.journal.path)
    d = sched.stats.as_dict()
    d.update({f"pool_{k}": v for k, v in sched.pool.as_dict().items()})
    d["_results"] = sched.results
    if mode == "paged":
        # the instrumented arm's artifacts: Chrome-trace ring buffer +
        # Prometheus exposition (uploaded by CI alongside the summary)
        d["_trace"] = sched.telemetry.tracer.export()
        d["_prom"] = telemetry_mod.scheduler_prometheus(sched)
    return d


def run(report: CsvReport, quick: bool = False, json_path: str = None,
        trace_path: str = None, prom_path: str = None):
    cfg = get_config("qwen3-0.6b", smoke=True)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    n = 36 if quick else 60
    reqs = build_trace(cfg, n)

    # warm every jit cache with the FULL trace (a truncated warm trace
    # misses chunk/table-width shape buckets and the measured run pays
    # the compile), then run the configs round-robin and report each
    # one's median of 5, so slow-machine drift hits all configs alike
    modes = ("static", "dense", "paged", "paged_notel", "paged_journal",
             "spec")
    if jax.device_count() >= MESH_DEVICES:
        modes = modes + ("mesh",)
    else:
        print(f"# fig14 mesh arm SKIPPED: needs {MESH_DEVICES} devices, "
              f"have {jax.device_count()} (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=8)")
    for mode in modes:
        serve_once(cfg, params, reqs, mode)
    runs = {m: [] for m in modes}
    for _ in range(5):
        for mode in modes:
            runs[mode].append(serve_once(cfg, params, reqs, mode))

    out = {}
    for mode in modes:
        d = sorted(runs[mode], key=lambda r: r["tokens_per_s"])[2]
        out[mode] = d
        util = d["decode_tokens"] / max(d["decode_slot_steps"], 1)
        print(f"# fig14 {mode}: {d['tokens_per_s']:.1f} tok/s "
              f"ttft_mean={d['ttft_mean_s'] * 1e3:.0f}ms "
              f"ttft_p95={d['ttft_p95_s'] * 1e3:.0f}ms "
              f"decode_steps={d['decode_steps']} util={util:.2f} "
              f"completed={d['completed']} rejected={d['rejected']} "
              f"page_high_water={d['pool_high_water_blocks']}"
              f"/{d['pool_num_blocks']}")
        report.add(f"fig14_{mode}_tok_per_s",
                   1e6 / max(d["tokens_per_s"], 1e-9),
                   f"tok/s={d['tokens_per_s']:.1f}")
        report.add(f"fig14_{mode}_ttft_mean",
                   d["ttft_mean_s"] * 1e6,
                   f"p95={d['ttft_p95_s'] * 1e6:.0f}us")

    # the dense ceiling rejects the long request; paged admits it
    assert out["dense"]["rejected"] >= 1, "long request should not fit dense"
    assert out["paged"]["rejected"] == 0 and \
        out["paged"]["completed"] == len(reqs), \
        "paged pool must admit the beyond-ceiling request"
    print(f"# fig14 long request ({LONG_PROMPT}+{LONG_NEW} tokens > dense "
          f"ceiling {DENSE_MAX_LEN}): dense rejected, paged served")
    print(f"# fig14 paged prefix cache: "
          f"hits={out['paged']['pool_prefix_hits']} "
          f"shared_tokens={out['paged']['pool_prefix_shared_tokens']} "
          f"prefill_chunks={out['paged']['prefill_chunks']}")

    # telemetry must not change WHAT is served (token identity) and
    # must cost <= 5% tokens/s vs the same config with tracing off
    for rid, toks in out["paged"]["_results"].items():
        assert out["paged_notel"]["_results"][rid].tolist() \
            == toks.tolist(), \
            f"telemetry changed the served tokens on {rid!r}"
    def settle_overhead(base_mode: str, arm_mode: str) -> float:
        """Best-case overhead, re-measured with 8 extra back-to-back
        twin pairs when the first estimate exceeds the budget — a noisy
        neighbor on the first rounds should not fail the lane, a real
        regression still does."""
        oh = bestcase_overhead(runs, base_mode, arm_mode)
        if oh > 0.05:
            print(f"# fig14 {arm_mode} overhead {oh * 100:.1f}% over "
                  f"budget on first rounds; re-measuring back-to-back")
            for _ in range(8):
                runs[base_mode].append(
                    serve_once(cfg, params, reqs, base_mode))
                runs[arm_mode].append(
                    serve_once(cfg, params, reqs, arm_mode))
            oh = bestcase_overhead(runs, base_mode, arm_mode)
        return oh

    overhead = settle_overhead("paged_notel", "paged")
    print(f"# fig14 telemetry overhead (paged vs --no-telemetry twin, "
          f"best of repeats): {overhead * 100:.1f}%")
    assert overhead <= 0.05, \
        f"telemetry overhead {overhead * 100:.1f}% exceeds the 5% budget"

    # the journal must not change WHAT is served (token identity) and
    # durability (flush per step + interval-bounded fsync) must cost
    # <= 5% tokens/s vs the same config with no journal attached
    for rid, toks in out["paged"]["_results"].items():
        assert out["paged_journal"]["_results"][rid].tolist() \
            == toks.tolist(), \
            f"journal changed the served tokens on {rid!r}"
    journal_overhead = settle_overhead("paged", "paged_journal")
    print(f"# fig14 journal overhead (paged_journal vs paged, flush "
          f"per step + interval fsync, best of repeats): "
          f"{journal_overhead * 100:.1f}%")
    assert journal_overhead <= 0.05, \
        f"journal overhead {journal_overhead * 100:.1f}% exceeds " \
        "the 5% budget"

    # every completed request must leave a full trace chain in the
    # exported ring buffer: enqueue -> first_token -> finish
    trace = out["paged"]["_trace"]
    by_rid = {}
    for ev in trace["traceEvents"]:
        rid = ev.get("args", {}).get("rid")
        if rid is not None:
            by_rid.setdefault(rid, set()).add(ev["name"])
    for rid in out["paged"]["_results"]:
        names = by_rid.get(str(rid), set())
        assert {"enqueue", "first_token", "finish"} <= names, \
            f"incomplete trace chain for {rid!r}: {sorted(names)}"
    print(f"# fig14 trace: {len(trace['traceEvents'])} events, full "
          f"enqueue->first_token->finish chains for "
          f"{len(out['paged']['_results'])} requests "
          f"(dropped={trace['otherData']['dropped']})")

    # speculative decoding must be TOKEN-IDENTICAL to the paged arm
    # (temperature 0): every emitted token is a target sample
    for rid, toks in out["paged"]["_results"].items():
        assert out["spec"]["_results"][rid].tolist() == toks.tolist(), \
            f"spec arm diverged from target-only decode on {rid!r}"
    print(f"# fig14 spec == paged token-identical "
          f"({out['spec']['completed']} requests); accept_rate="
          f"{out['spec']['spec_accept_rate'] * 100:.0f}% "
          f"(self-draft upper bound, K={SPEC_TOKENS}) "
          f"verify_rounds={out['spec']['spec_rounds']} "
          f"vs paged decode_steps={out['paged']['decode_steps']}")

    # the mesh arm must schedule the trace identically (same admissions,
    # nothing rejected) ...
    if "mesh" in out:
        assert out["mesh"]["rejected"] == 0 and \
            out["mesh"]["completed"] == len(reqs), \
            "mesh arm must admit the whole trace"
        # ... and be TOKEN-IDENTICAL to single-device serving.  The
        # identity assertion runs one untimed float32 pass of each:
        # the timed arms serve in bfloat16, where resharding reorders
        # accumulation (TP splits the o_proj/lm_head contractions) and
        # the last mantissa bit can flip an argmax near a tie — a
        # numerics property of the dtype, not a scheduler divergence.
        import dataclasses
        cfg32 = dataclasses.replace(cfg, dtype="float32")
        params32, _ = init_lm(cfg32, jax.random.PRNGKey(0))
        reqs32 = build_trace(cfg32, n)
        p32 = serve_once(cfg32, params32, reqs32, "paged")
        m32 = serve_once(cfg32, params32, reqs32, "mesh")
        for rid, toks in p32["_results"].items():
            assert m32["_results"][rid].tolist() == toks.tolist(), \
                f"mesh arm diverged from single-device serving on {rid!r}"
        print(f"# fig14 mesh == paged token-identical at f32 "
              f"({m32['completed']} requests) on a "
              f"{MESH_SHAPE[0]}x{MESH_SHAPE[1]} (data, model) mesh; "
              f"bf16 arm: {out['mesh']['tokens_per_s']:.1f} tok/s on "
              f"emulated devices (mechanics check, not a speedup claim)")

    cont = out["dense"]["tokens_per_s"] / \
        max(out["static"]["tokens_per_s"], 1e-9)
    paged = out["paged"]["tokens_per_s"] / \
        max(out["dense"]["tokens_per_s"], 1e-9)
    spec = out["spec"]["tokens_per_s"] / \
        max(out["paged"]["tokens_per_s"], 1e-9)
    print(f"# fig14 continuous/static tokens/s speedup: {cont:.2f}x")
    print(f"# fig14 paged+chunked/dense-continuous tokens/s speedup "
          f"(equal memory): {paged:.2f}x")
    print(f"# fig14 spec/paged tokens/s ratio (self-draft upper bound, "
          f"CPU oracle): {spec:.2f}x")
    report.add("fig14_continuous_speedup", cont * 100, f"{cont:.2f}x")
    report.add("fig14_paged_speedup", paged * 100, f"{paged:.2f}x")
    report.add("fig14_spec_speedup", spec * 100, f"{spec:.2f}x")
    report.add("fig14_spec_accept_rate",
               out["spec"]["spec_accept_rate"] * 100,
               f"{out['spec']['spec_accept_rate'] * 100:.0f}%")

    if json_path:
        summary = {
            "trace": {"requests": len(reqs), "sys_prefix": SYS_LEN,
                      "pool_tokens": POOL_TOKENS,
                      "dense_max_len": DENSE_MAX_LEN,
                      "long_request": LONG_PROMPT + LONG_NEW,
                      "spec_tokens": SPEC_TOKENS,
                      "mesh_shape": list(MESH_SHAPE)
                      if "mesh" in out else None},
            "speedup_paged_vs_dense": paged,
            "speedup_continuous_vs_static": cont,
            "speedup_spec_vs_paged": spec,
            "telemetry_overhead": overhead,
            "journal_overhead": journal_overhead,
            "mesh_token_identical": "mesh" in out,
            "configs": {m: {
                "tokens_per_s": d["tokens_per_s"],
                "ttft_mean_s": d["ttft_mean_s"],
                "ttft_p95_s": d["ttft_p95_s"],
                "completed": d["completed"],
                "rejected": d["rejected"],
                "decode_steps": d["decode_steps"],
                "page_high_water": d["pool_high_water_blocks"],
                "prefix_hits": d.get("pool_prefix_hits", 0),
                "prefix_shared_tokens":
                    d.get("pool_prefix_shared_tokens", 0),
                "spec_accept_rate": d.get("spec_accept_rate", 0.0),
                "spec_rounds": d.get("spec_rounds", 0),
                "spec_draft_accepted": d.get("spec_draft_accepted", 0),
                "spec_draft_proposed": d.get("spec_draft_proposed", 0),
            } for m, d in out.items()},
        }
        with open(json_path, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"# fig14 wrote {json_path}")
    if trace_path:
        with open(trace_path, "w") as f:
            json.dump(trace, f)
        print(f"# fig14 wrote {trace_path} (Perfetto/chrome://tracing)")
    if prom_path:
        with open(prom_path, "w") as f:
            f.write(out["paged"]["_prom"])
        print(f"# fig14 wrote {prom_path} (Prometheus exposition)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write BENCH_serving.json summary here")
    ap.add_argument("--trace-out", default=None,
                    help="write the paged arm's Chrome-trace JSON here")
    ap.add_argument("--prom-out", default=None,
                    help="write the paged arm's Prometheus scrape here")
    args = ap.parse_args()
    r = CsvReport()
    run(r, quick=args.quick, json_path=args.json,
        trace_path=args.trace_out, prom_path=args.prom_out)
    r.dump()
