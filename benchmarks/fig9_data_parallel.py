"""Paper Fig. 9 — small-scale data parallelism limits.

Strong-scales one CycleGAN trainer by splitting the fixed 128-sample
mini-batch over 1..16 simulated GPUs.  Per-device compute time is
MEASURED on CPU (jit'd train step at per-device batch 128/N); the
gradient all-reduce time is DERIVED from model size and NVLink/IB
bandwidths (the paper's hardware), reproducing the efficiency collapse
the paper observes past ~16 GPUs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (PAPER_BATCH, PAPER_OPT, CsvReport,
                               timeit)
from repro.train.steps import make_gan_steps

# comm model: V100 4-GPU NVLink node + EDR IB across nodes (paper's Lassen)
NVLINK_BW = 150e9      # bytes/s effective all-reduce within node
IB_BW = 12.5e9         # bytes/s per rail EDR, 2 rails
LATENCY = 20e-6


def allreduce_time(nbytes: float, n: int) -> float:
    if n <= 1:
        return 0.0
    bw = NVLINK_BW if n <= 4 else 2 * IB_BW
    return 2 * nbytes * (n - 1) / n / bw + LATENCY * np.log2(n)


def run(report: CsvReport, quick: bool = False):
    # fig9 needs per-device compute >> dispatch overhead: use the paper's
    # full 64x64-image CycleGAN so splitting the 128-batch matters.
    from repro.configs.icf_cyclegan import CycleGANConfig
    big_cfg = CycleGANConfig(image_size=32 if quick else 64,
                             enc_hidden=(1024, 256),
                             dec_hidden=(256, 1024))
    from repro.data import jag as jag_mod
    xs = jag_mod.sample_inputs(1024, 0)
    sim = jag_mod.jag_simulate(xs, big_cfg.image_size)
    x, y = sim["x"], jag_mod.flatten_outputs(sim)
    init, train_step, metric = make_gan_steps(big_cfg, PAPER_OPT)
    params, opt_state, hparams = init(0)
    grad_bytes = sum(l.size * 4 for l in jax.tree.leaves(params))
    steps_per_epoch = (4096 if quick else 16384) // PAPER_BATCH

    rows = []
    base_epoch = None
    for n_gpu in (1, 2, 4, 8, 16):
        b = max(1, PAPER_BATCH // n_gpu)
        batch = {"x": jnp.asarray(x[:b]), "y": jnp.asarray(y[:b])}
        st = [params, opt_state]

        def step():
            st[0], st[1], _ = train_step(st[0], st[1], batch, hparams)
            return st[0]

        t_step = timeit(step, warmup=2, iters=4 if quick else 10)
        t_comm = allreduce_time(grad_bytes, n_gpu)
        epoch = steps_per_epoch * (t_step + t_comm)
        base_epoch = base_epoch or epoch
        speedup = base_epoch / epoch
        eff = speedup / n_gpu
        rows.append((n_gpu, epoch, speedup, eff))
        report.add(f"fig9/dp_gpus={n_gpu}", t_step * 1e6,
                   f"epoch_s={epoch:.2f};speedup={speedup:.2f};"
                   f"efficiency={eff:.2f}")
    return rows


if __name__ == "__main__":
    r = CsvReport()
    run(r)
    r.dump()
