"""Shared benchmark plumbing: dataset construction, timing, CSV output."""
from __future__ import annotations

import time
from typing import Callable, List

import jax
import numpy as np

from repro.configs.base import OptimizerConfig
from repro.configs.icf_cyclegan import CycleGANConfig
from repro.data import jag

# benchmark-scale CycleGAN: 16x16 images keep the 1-core CPU runs honest
# but fast; the modality structure (5 -> 15 scalars + 12 images) is intact.
BENCH_CCFG = CycleGANConfig(
    name="icf-cyclegan-bench", image_size=16,
    fwd_hidden=(64, 128, 64), inv_hidden=(64, 128, 64),
    disc_hidden=(64, 64), enc_hidden=(256, 64), dec_hidden=(64, 256))

PAPER_BATCH = 128        # paper Section IV: mini-batch 128, Adam lr 1e-3
PAPER_OPT = OptimizerConfig(name="adam", lr=1e-3, warmup_steps=1,
                            grad_clip_norm=0.0)


def make_jag_arrays(n: int, seed: int = 0):
    xs = jag.sample_inputs(n, seed)
    sim = jag.jag_simulate(xs, BENCH_CCFG.image_size)
    return sim["x"], jag.flatten_outputs(sim)


def make_jag_bundles(root: str, n: int, samples_per_file: int = 512,
                     seed: int = 0) -> List[str]:
    """On-disk bundle manifest at the benchmark image size (reuses an
    existing manifest of the right length when present)."""
    files = jag.list_bundles(root)
    if len(files) == (n + samples_per_file - 1) // samples_per_file:
        return files
    return jag.write_bundles(root, n, samples_per_file,
                             image_size=BENCH_CCFG.image_size, seed=seed)


def timeit(fn: Callable, warmup: int = 2, iters: int = 10) -> float:
    """Times fn, blocking on its return value (async dispatch safe)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters


def silo_partition(x: np.ndarray, K: int, key_dim: int = 0) -> list:
    """The paper's data-silo scenario: partition sample indices into K
    contiguous regions of parameter space (sorted along `key_dim`).
    Quasi-random (Halton) index ranges still cover the space, so genuine
    silos must be cut in INPUT space, not index space."""
    order = np.argsort(x[:, key_dim], kind="stable")
    return [order[k * len(order) // K:(k + 1) * len(order) // K]
            for k in range(K)]


class CsvReport:
    """Collects `name,us_per_call,derived` rows (benchmarks/run.py format)."""

    def __init__(self):
        self.rows: List[str] = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        self.rows.append(f"{name},{us_per_call:.1f},{derived}")

    def dump(self):
        print("name,us_per_call,derived")
        for r in self.rows:
            print(r)
