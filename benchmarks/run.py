"""Benchmark runner: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks datasets
for CI-speed runs; default sizes match EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated figure list, e.g. fig10,fig11")
    args = ap.parse_args(argv)

    from benchmarks.common import CsvReport
    from benchmarks import (fig9_data_parallel, fig10_datastore,
                            fig11_ltfb_scaling, fig12_quality,
                            fig13_kindependent, fig14_serving, roofline)

    suites = {
        "fig9": fig9_data_parallel.run,
        "fig10": fig10_datastore.run,
        "fig11": fig11_ltfb_scaling.run,
        "fig12": fig12_quality.run,
        "fig13": fig13_kindependent.run,
        "fig14": fig14_serving.run,
        "roofline": roofline.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    report = CsvReport()
    failed = []
    for name, fn in suites.items():
        try:
            fn(report, quick=args.quick)
        except Exception as e:
            failed.append(name)
            print(f"# {name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    report.dump()
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
