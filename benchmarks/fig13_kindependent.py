"""Paper Fig. 13 — LTFB vs partitioned K-independent training.

Equal runtimes (same number of per-trainer iterations) and equal memory
footprints; the K-independent baseline trains K models on disjoint 1/K
subsets and takes the best final validation loss.  LTFB should match or
beat it, with the gap widening as K grows (paper's key comparison)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (BENCH_CCFG, PAPER_BATCH, PAPER_OPT,
                               CsvReport, make_jag_arrays, silo_partition)
from repro.core.population import Population, TrainerFns
from repro.train.steps import make_gan_steps


def run(report: CsvReport, quick: bool = False):
    n = 8_192 if quick else 16_384
    x, y = make_jag_arrays(n + 1024, seed=2)
    val = {"x": jnp.asarray(x[n:]), "y": jnp.asarray(y[n:])}
    init, train_step, metric = make_gan_steps(BENCH_CCFG, PAPER_OPT)
    fns = TrainerFns(init, train_step, metric)

    rounds, steps = (16, 10) if quick else (24, 15)
    rows = []
    for K in (2, 4, 8):
        def mk(base_seed):
            # contiguous silos (paper scenario) — K-independent trainers
            # generalize poorly on unseen regions; LTFB propagates winners
            silos = silo_partition(x[:n], K)
            def loader_for(k):
                rng = np.random.default_rng(base_seed + k)
                pool = silos[k]
                def loader():
                    idx = rng.choice(pool, PAPER_BATCH)
                    return {"x": jnp.asarray(x[idx]),
                            "y": jnp.asarray(y[idx])}
                return loader
            loaders = [loader_for(k) for k in range(K)]
            tb = [[{"x": jnp.asarray(x[silos[k][:256]]),
                    "y": jnp.asarray(y[silos[k][:256]])}]
                  for k in range(K)]
            return loaders, tb

        def pop_mean(pop):
            return float(np.mean([float(metric(t.params, val))
                                  for t in pop.trainers]))

        loaders, tb = mk(10)
        ltfb_pop = Population(fns, loaders, tb, scope="generator", seed=K,
                              perturb_hparams=False)
        ltfb_pop.run(rounds=rounds, steps_per_round=steps)
        v_ltfb = pop_mean(ltfb_pop)

        loaders, tb = mk(10)     # identical data/seeds, no tournaments
        ind_pop = Population(fns, loaders, tb, scope="generator", seed=K,
                             perturb_hparams=False)
        for _ in range(rounds):
            ind_pop.train_round(steps)
        v_ind = pop_mean(ind_pop)

        rows.append((K, v_ltfb, v_ind, v_ind / v_ltfb))
        report.add(f"fig13/k={K}", 0.0,
                   f"ltfb_val={v_ltfb:.4f};kindep_val={v_ind:.4f};"
                   f"ltfb_advantage={v_ind / v_ltfb:.2f}x")
    return rows


if __name__ == "__main__":
    r = CsvReport()
    run(r)
    r.dump()
