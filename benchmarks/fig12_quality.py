"""Paper Fig. 12 — model quality vs trainer count at fixed per-trainer
iterations.  LTFB at larger K reaches BETTER validation loss for the
same per-trainer step budget (each exchanged winner encodes other
partitions' data)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (BENCH_CCFG, PAPER_BATCH, PAPER_OPT,
                               CsvReport, make_jag_arrays, silo_partition)
from repro.core.population import Population, TrainerFns
from repro.train.steps import make_gan_steps


def run(report: CsvReport, quick: bool = False):
    n = 8_192 if quick else 16_384
    x, y = make_jag_arrays(n + 1024, seed=1)
    val = {"x": jnp.asarray(x[n:]), "y": jnp.asarray(y[n:])}
    init, train_step, metric = make_gan_steps(BENCH_CCFG, PAPER_OPT)
    fns = TrainerFns(init, train_step, metric)

    rounds, steps = (16, 10) if quick else (24, 15)
    rows = []
    base = None
    for K in (1, 2, 4, 8):
        # contiguous silos (the paper's scenario: data written in
        # exploration order, partitions cover different input regions)
        silos = silo_partition(x[:n], K)
        def loader_for(k):
            rng = np.random.default_rng(1000 + k)
            pool = silos[k]
            def loader():
                idx = rng.choice(pool, PAPER_BATCH)
                return {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}
            return loader

        loaders = [loader_for(k) for k in range(K)]
        tb = [[{"x": jnp.asarray(x[silos[k][:256]]),
                "y": jnp.asarray(y[silos[k][:256]])}]
              for k in range(K)]
        pop = Population(fns, loaders, tb, scope="generator", seed=K)
        pop.run(rounds=rounds, steps_per_round=steps)
        # deployed-model statistic: any surviving trainer's model (mean),
        # plus the single best for reference
        vals = [float(metric(t.params, val)) for t in pop.trainers]
        vloss = float(np.mean(vals))
        vbest = min(vals)
        base = base or vloss
        improvement = base / vloss
        rows.append((K, vloss, improvement))
        report.add(f"fig12/quality_trainers={K}", 0.0,
                   f"val_mean={vloss:.4f};val_best={vbest:.4f};"
                   f"improvement_vs_k1={improvement:.2f}")
    return rows


if __name__ == "__main__":
    r = CsvReport()
    run(r)
    r.dump()
