"""Paper Fig. 10 — data store modes (none / dynamic / preload).

REAL file I/O: JAG bundles are written to disk in exploration order
(the paper's pathological layout), then two epochs of random-minibatch
assembly run under each mode.  Reported: initial-epoch and steady-state
epoch times + file-open counts — reproducing the paper's finding that
the naive reader is dominated by file opens while the store pays only
during epoch 1 (dynamic) or a parallel preload (preload).
"""
from __future__ import annotations

import tempfile
import time

from benchmarks.common import BENCH_CCFG, CsvReport
from repro.data import jag
from repro.datastore.store import DataStore


def _epoch(store: DataStore, epoch: int, batch: int) -> float:
    perm = store.epoch_permutation(epoch)
    spe = store.steps_per_epoch(batch)
    t0 = time.perf_counter()
    for s in range(spe):
        store.get_batch(perm, s, batch)
    return time.perf_counter() - t0


def run(report: CsvReport, quick: bool = False):
    n = 4_000 if quick else 16_000
    per_file = 250
    with tempfile.TemporaryDirectory() as root:
        paths = jag.write_bundles(root, n, per_file,
                                  image_size=BENCH_CCFG.image_size, seed=0)
        rows = []
        for mode in ("none", "dynamic", "preload"):
            store = DataStore(paths, jag.read_bundle, num_ranks=4,
                              mode=mode)
            t_pre = 0.0
            if mode == "preload":
                store.preload(parallel=True)
                t_pre = store.stats.preload_seconds
            t_first = _epoch(store, 0, 128) + t_pre
            t_steady = _epoch(store, 1, 128)
            rows.append((mode, t_first, t_steady, store.stats.file_opens))
            report.add(f"fig10/store={mode}", t_steady * 1e6,
                       f"first_epoch_s={t_first:.2f};"
                       f"steady_epoch_s={t_steady:.2f};"
                       f"file_opens={store.stats.file_opens}")
        return rows


if __name__ == "__main__":
    r = CsvReport()
    run(r)
    r.dump()
